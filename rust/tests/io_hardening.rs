//! Hardened readers under hostile input: forged length fields, truncated
//! sections, and corrupted payloads must surface as *named* errors —
//! never a capacity-overflow panic, never a multi-GB allocation that the
//! OOM killer resolves, never a plausible-but-wrong graph.
//!
//! Also pins the loader-equivalence contract: the zero-copy mapped
//! `.lgx` loader and the buffered `read_exact` loader produce
//! bit-identical graphs from the same file, and corruption fails by name
//! through *both* paths (parse errors never silently fall back).

use labor_gnn::graph::builder::CscBuilder;
use labor_gnn::graph::compact::VertexPerm;
use labor_gnn::graph::gen::{dc_sbm, DcSbmConfig};
use labor_gnn::graph::io::{
    load_graph, load_lgx, load_lgx_buffered, load_lgx_mmap, mmap_enabled, read_f32_slice,
    read_graph, read_u16_slice, read_u32_slice, read_u64_slice, save_graph, save_lgx,
    write_graph, LgxError,
};
use labor_gnn::graph::CscGraph;
use std::io::ErrorKind;
use std::path::PathBuf;

fn dense_graph() -> CscGraph {
    dc_sbm(&DcSbmConfig {
        num_vertices: 300,
        num_arcs: 6_000,
        num_communities: 3,
        homophily: 0.7,
        degree_exponent: 0.5,
        seed: 19,
    })
    .graph
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("labor_iohard_{tag}_{}.bin", std::process::id()))
}

/// A length-prefixed section whose header declares `declared` elements,
/// followed by `payload` bytes.
fn forged_section(declared: u64, payload: &[u8]) -> Vec<u8> {
    let mut buf = declared.to_le_bytes().to_vec();
    buf.extend_from_slice(payload);
    buf
}

// --- legacy length-prefixed readers ----------------------------------

/// `u64::MAX` as a declared element count must fail by name in every
/// legacy reader. Before hardening this was `vec![0u8; n * width]` on the
/// raw count: a capacity-overflow panic (`n * width` wrapping) or an
/// attempted 16-exabyte allocation.
#[test]
fn forged_u64_max_length_is_a_named_error_in_every_reader() {
    let buf = forged_section(u64::MAX, &[0u8; 64]);
    let errors = [
        read_u32_slice(&mut &buf[..]).unwrap_err(),
        read_u64_slice(&mut &buf[..]).unwrap_err(),
        read_f32_slice(&mut &buf[..]).unwrap_err(),
        read_u16_slice(&mut &buf[..]).unwrap_err(),
    ];
    for err in errors {
        assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");
        assert!(
            err.to_string().contains("overflow"),
            "error must name the overflow: {err}"
        );
    }
}

/// A declared count whose byte size fits `usize` but not the machine
/// (2⁶¹ u32 elements = 2⁶³ bytes) fails at the up-front reservation with
/// a named error — the allocator refusal is caught, not unwrapped.
#[test]
fn forged_exabyte_length_fails_reservation_by_name() {
    let buf = forged_section(1u64 << 61, &[0u8; 64]);
    let err = read_u32_slice(&mut &buf[..]).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");
    assert!(err.to_string().contains("cannot allocate"), "{err}");
}

/// A plausible-but-wrong count (file ends first) is a named truncation
/// error carrying the declared count — including the off-by-one case and
/// a count that crosses the chunked-read boundary.
#[test]
fn declared_count_beyond_eof_is_a_named_truncation() {
    // 8 u32s on disk, 9 declared (off by one)
    let payload: Vec<u8> = (0..8u32).flat_map(|x| x.to_le_bytes()).collect();
    let buf = forged_section(9, &payload);
    let err = read_u32_slice(&mut &buf[..]).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");
    assert!(err.to_string().contains("file ends before the declared 9"), "{err}");

    // a declared count larger than one read chunk (2²⁰ bytes), 5 bytes on
    // disk: the chunked reader must hit EOF after one chunk, not zero-fill
    // the whole declared size first
    let buf = forged_section(1 << 20, &[1, 2, 3, 4, 5]);
    let err = read_u32_slice(&mut &buf[..]).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");
    assert!(err.to_string().contains("file ends before the declared"), "{err}");
}

/// An honest section still round-trips through the hardened reader.
#[test]
fn honest_sections_still_roundtrip() {
    let xs: Vec<u32> = (0..1000).map(|i| i * 7).collect();
    let payload: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
    let buf = forged_section(xs.len() as u64, &payload);
    assert_eq!(read_u32_slice(&mut &buf[..]).unwrap(), xs);
}

/// The legacy whole-graph reader inherits the hardening: a forged indptr
/// length inside an otherwise valid file is a named error, not a panic.
#[test]
fn legacy_graph_with_forged_section_length_is_rejected() {
    let g = dense_graph();
    let mut buf = Vec::new();
    write_graph(&mut buf, &g).unwrap();
    // the indptr length prefix sits right after the 8-byte magic
    buf[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
    let err = read_graph(&mut &buf[..]).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");

    // and a mid-file truncation through the file loader is named too
    let path = tmp_path("legacy_trunc");
    save_graph(&path, &g).unwrap();
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    let err = load_graph(&path).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidData, "{err}");
    std::fs::remove_file(&path).ok();
}

// --- .lgx: mapped loader vs buffered loader --------------------------

/// The loader-equivalence contract: the same `.lgx` file loads
/// bit-identically through the zero-copy mapped path and the buffered
/// `read_exact` path — graph, weights, and permutation.
#[test]
fn mmap_and_buffered_loads_are_bit_identical() {
    let g = dense_graph();
    let perm = VertexPerm::degree_ordered(&g);
    let rg = perm.apply_to_graph(&g);
    let path = tmp_path("identity");
    save_lgx(&path, &rg, Some(&perm)).unwrap();

    let (buffered, perm_b) = load_lgx_buffered(&path).unwrap();
    assert!(!buffered.is_mapped());
    assert_eq!(buffered, rg);
    assert_eq!(perm_b.as_ref(), Some(&perm));

    if mmap_enabled() {
        let (mapped, perm_m) = load_lgx_mmap(&path).unwrap();
        assert!(mapped.is_mapped(), "forced mmap load must be backed by the mapping");
        assert_eq!(mapped, buffered, "mapped and buffered loads must be bit-identical");
        assert_eq!(perm_m, perm_b);
        // the default entry point picks the mapped path on this target
        let (auto, _) = load_lgx(&path).unwrap();
        assert!(auto.is_mapped());
        assert_eq!(auto, buffered);
    }
    std::fs::remove_file(&path).ok();
}

/// A mapped graph answers the same queries as its owned twin (the
/// `GraphBuf` windows really do point at the right file bytes).
#[test]
fn mapped_graph_answers_queries_identically() {
    if !mmap_enabled() {
        return;
    }
    let g = dense_graph();
    let path = tmp_path("queries");
    save_lgx(&path, &g, None).unwrap();
    let (m, _) = load_lgx_mmap(&path).unwrap();
    assert_eq!(m.num_vertices(), g.num_vertices());
    assert_eq!(m.num_edges(), g.num_edges());
    for s in 0..g.num_vertices() as u32 {
        assert_eq!(m.in_neighbors(s), g.in_neighbors(s), "vertex {s}");
    }
    std::fs::remove_file(&path).ok();
}

/// Corruption fails by name through the mapped loader exactly as through
/// the buffered one — a parse error must never silently fall back.
#[test]
fn mapped_loader_names_corruption_and_truncation() {
    if !mmap_enabled() {
        return;
    }
    let g = dense_graph();
    let path = tmp_path("corrupt");
    save_lgx(&path, &g, None).unwrap();
    let full = std::fs::read(&path).unwrap();

    // one flipped payload byte (inside the indptr section) → checksum
    let mut c = full.clone();
    c[70] ^= 0x01;
    std::fs::write(&path, &c).unwrap();
    match load_lgx_mmap(&path) {
        Err(LgxError::ChecksumMismatch { expected, got }) => assert_ne!(expected, got),
        other => panic!("expected ChecksumMismatch, got {other:?}"),
    }
    // the default entry point reports the same named error (no fallback)
    match load_lgx(&path) {
        Err(LgxError::ChecksumMismatch { .. }) => {}
        other => panic!("load_lgx must not mask corruption, got {other:?}"),
    }

    // a file cut mid-section → named truncation (bounds are checked
    // against the mapping before any section is touched)
    for keep in [10usize, 63, 64, 100, full.len() - 1] {
        std::fs::write(&path, &full[..keep]).unwrap();
        match load_lgx_mmap(&path) {
            Err(LgxError::Truncated(section)) => assert!(!section.is_empty()),
            other => panic!("keep {keep}: expected Truncated, got {other:?}"),
        }
    }
    std::fs::remove_file(&path).ok();
}

/// A forged header declaring billions of edges (within the |V|² bound,
/// wide flag set, header re-signed so only section mathematics can
/// object) dies as a named truncation in both loaders — the section size
/// is computed and bounds-checked before any allocation or read.
#[test]
fn forged_giant_edge_count_is_truncation_not_oom() {
    fn fnv(bytes: &[u8]) -> u64 {
        bytes
            .iter()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
    }
    let g = CscBuilder::new(2).edges(&[(0, 1)]).build().unwrap();
    let path = tmp_path("giant");
    save_lgx(&path, &g, None).unwrap();
    let mut buf = std::fs::read(&path).unwrap();
    buf[16..24].copy_from_slice(&1_000_000u64.to_le_bytes()); // nv
    buf[24..32].copy_from_slice(&10_000_000_000u64.to_le_bytes()); // ne: 40 GB of indices
    let flags = u32::from_le_bytes(buf[12..16].try_into().unwrap()) | 0b10; // wide indptr
    buf[12..16].copy_from_slice(&flags.to_le_bytes());
    let hsum = fnv(&buf[..40]);
    buf[40..48].copy_from_slice(&hsum.to_le_bytes());
    std::fs::write(&path, &buf).unwrap();

    match load_lgx_buffered(&path) {
        Err(LgxError::Truncated(_)) => {}
        other => panic!("buffered: expected Truncated, got {other:?}"),
    }
    if mmap_enabled() {
        match load_lgx_mmap(&path) {
            Err(LgxError::Truncated(_)) => {}
            other => panic!("mapped: expected Truncated, got {other:?}"),
        }
    }
    std::fs::remove_file(&path).ok();
}

/// An empty file cannot be mapped; the default entry point falls back to
/// the buffered loader and reports the same named header truncation a
/// buffered-only build would.
#[test]
fn empty_file_falls_back_and_names_the_header() {
    let path = tmp_path("empty");
    std::fs::write(&path, b"").unwrap();
    match load_lgx(&path) {
        Err(LgxError::Truncated(section)) => assert_eq!(section, "header"),
        other => panic!("expected Truncated(header), got {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}
