//! `.lgx` zero-copy binary format: round-trip fidelity, corruption
//! rejection with named errors, and indptr width selection.
//!
//! The contract under test: a load either reproduces the written graph
//! (and permutation) exactly, or fails with a [`LgxError`] naming what is
//! wrong — a corrupt file must never come back as a plausible-but-wrong
//! graph.

use labor_gnn::graph::builder::CscBuilder;
use labor_gnn::graph::compact::VertexPerm;
use labor_gnn::graph::gen::{dc_sbm, DcSbmConfig};
use labor_gnn::graph::io::{
    load_lgx, load_lgx_buffered_full, load_lgx_full, load_lgx_mmap_full, read_lgx,
    read_lgx_full, save_lgx, save_lgx_full, write_lgx, write_lgx_full, LgxError, LGX_VERSION,
};
use labor_gnn::graph::partition::{ldg_partition, partition_layout};
use labor_gnn::graph::{CscGraph, IndPtr, PartitionMap};

fn dense_graph() -> CscGraph {
    dc_sbm(&DcSbmConfig {
        num_vertices: 400,
        num_arcs: 9_000,
        num_communities: 4,
        homophily: 0.7,
        degree_exponent: 0.6,
        seed: 11,
    })
    .graph
}

fn weighted_graph() -> CscGraph {
    let mut b = CscBuilder::new(6);
    b.weighted_edge(0, 1, 2.0);
    b.weighted_edge(3, 1, 0.5);
    b.weighted_edge(4, 2, 1.25);
    b.weighted_edge(5, 2, 3.5);
    b.weighted_edge(1, 5, 0.75);
    b.build().unwrap()
}

fn to_bytes(g: &CscGraph, perm: Option<&VertexPerm>) -> Vec<u8> {
    let mut buf = Vec::new();
    write_lgx(&mut buf, g, perm).unwrap();
    buf
}

#[test]
fn roundtrip_unweighted_no_perm() {
    let g = dense_graph();
    let buf = to_bytes(&g, None);
    let (back, perm) = read_lgx(&mut &buf[..]).unwrap();
    assert_eq!(back, g);
    assert!(perm.is_none());
    assert!(back.indptr.is_narrow(), "small graph must load with u32 offsets");
}

#[test]
fn roundtrip_weighted_with_perm() {
    let g = weighted_graph();
    let perm = VertexPerm::degree_ordered(&g);
    let rg = perm.apply_to_graph(&g);
    let buf = to_bytes(&rg, Some(&perm));
    let (back, back_perm) = read_lgx(&mut &buf[..]).unwrap();
    assert_eq!(back, rg);
    assert_eq!(back.weights, rg.weights, "weights must survive bit-exactly");
    assert_eq!(back_perm.as_ref(), Some(&perm));
    // the perm still maps relabeled ids back onto the original graph
    let p = back_perm.unwrap();
    for s in 0..rg.num_vertices() as u32 {
        for &t in back.in_neighbors(s) {
            assert!(g.has_edge(p.to_old(t), p.to_old(s)));
        }
    }
}

#[test]
fn roundtrip_through_a_file() {
    let g = dense_graph();
    let perm = VertexPerm::degree_ordered(&g);
    let rg = perm.apply_to_graph(&g);
    let path = std::env::temp_dir().join(format!("labor_lgx_{}.lgx", std::process::id()));
    save_lgx(&path, &rg, Some(&perm)).unwrap();
    let (back, back_perm) = load_lgx(&path).unwrap();
    assert_eq!(back, rg);
    assert_eq!(back_perm.as_ref(), Some(&perm));
    std::fs::remove_file(&path).ok();
}

#[test]
fn empty_and_edgeless_graphs_roundtrip() {
    let empty = CscBuilder::new(1).build().unwrap();
    let buf = to_bytes(&empty, None);
    let (back, _) = read_lgx(&mut &buf[..]).unwrap();
    assert_eq!(back, empty);
    let edgeless = CscBuilder::new(50).build().unwrap();
    let buf = to_bytes(&edgeless, None);
    let (back, _) = read_lgx(&mut &buf[..]).unwrap();
    assert_eq!(back.num_vertices(), 50);
    assert_eq!(back.num_edges(), 0);
}

#[test]
fn bad_magic_is_named() {
    let mut buf = to_bytes(&dense_graph(), None);
    buf[0] = b'X';
    match read_lgx(&mut &buf[..]) {
        Err(LgxError::BadMagic) => {}
        other => panic!("expected BadMagic, got {other:?}"),
    }
}

#[test]
fn corrupted_header_is_named() {
    let mut buf = to_bytes(&dense_graph(), None);
    buf[17] ^= 0xFF; // num_vertices byte: header checksum must catch it
    match read_lgx(&mut &buf[..]) {
        Err(LgxError::HeaderCorrupt { .. }) => {}
        other => panic!("expected HeaderCorrupt, got {other:?}"),
    }
}

#[test]
fn unsupported_version_is_named() {
    let mut buf = to_bytes(&dense_graph(), None);
    // bump the version field AND refresh the header checksum, so the
    // version check (not the checksum) is what fires
    buf[8] = (LGX_VERSION + 1) as u8;
    resign_header(&mut buf);
    match read_lgx(&mut &buf[..]) {
        Err(LgxError::UnsupportedVersion(v)) => assert_eq!(v, LGX_VERSION + 1),
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
}

/// FNV-1a 64 (mirror of the format's checksum, for test-side re-signing).
fn fnv(bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

#[test]
fn payload_corruption_is_named() {
    let g = dense_graph();
    let buf = to_bytes(&g, None);
    // flip one byte in the indptr region (offset 70) and deep inside the
    // indices region; each must surface as a checksum mismatch (or a
    // structural error — never a silent wrong load). Positions avoid the
    // zero padding between sections, which is alignment filler, not data.
    for pos in [70usize, 1730, buf.len() / 2] {
        let mut c = buf.clone();
        c[pos] ^= 0x01;
        match read_lgx(&mut &c[..]) {
            Err(LgxError::ChecksumMismatch { expected, got }) => assert_ne!(expected, got),
            Err(LgxError::Invalid(_)) => {} // structurally impossible values
            other => panic!("byte {pos}: expected a named corruption error, got {other:?}"),
        }
    }
}

#[test]
fn truncation_is_named_per_section() {
    let g = weighted_graph();
    let perm = VertexPerm::degree_ordered(&g);
    let full = to_bytes(&perm.apply_to_graph(&g), Some(&perm));
    // cutting anywhere must produce Truncated (header cut => Truncated("header"))
    for keep in [0usize, 10, 63, 64, 100, full.len() - 1] {
        let cut = &full[..keep];
        match read_lgx(&mut &cut[..]) {
            Err(LgxError::Truncated(section)) => assert!(!section.is_empty()),
            other => panic!("keep {keep}: expected Truncated, got {other:?}"),
        }
    }
}

#[test]
fn perm_that_is_not_a_bijection_is_rejected() {
    // hand-corrupt the perm section so checksums pass but the mapping is
    // invalid: rebuild the file from a forged VertexPerm is impossible
    // through the API, so splice bytes and re-sign the payload instead
    let g = CscBuilder::new(3).edges(&[(0, 1), (1, 2)]).build().unwrap();
    let perm = VertexPerm::identity(3);
    let mut buf = to_bytes(&g, Some(&perm));
    // perm section is the last 64-byte block; make forward = [0, 0, 1]
    let perm_off = buf.len() - 64;
    buf[perm_off..perm_off + 4].copy_from_slice(&0u32.to_le_bytes());
    buf[perm_off + 4..perm_off + 8].copy_from_slice(&0u32.to_le_bytes());
    buf[perm_off + 8..perm_off + 12].copy_from_slice(&1u32.to_le_bytes());
    // re-sign the payload so only the bijection check can object. The
    // checksum covers section bytes without padding; for this 3-vertex
    // graph: indptr 16 B @ 64, indices 8 B @ 128, perm 12 B @ 192.
    let mut sum = 0xcbf2_9ce4_8422_2325u64;
    sum = fnv_continue(sum, &buf[64..64 + 16]); // indptr (4 × u32)
    sum = fnv_continue(sum, &buf[128..128 + 8]); // indices (2 × u32)
    sum = fnv_continue(sum, &buf[perm_off..perm_off + 12]); // perm (3 × u32)
    buf[32..40].copy_from_slice(&sum.to_le_bytes());
    resign_header(&mut buf);
    match read_lgx(&mut &buf[..]) {
        Err(LgxError::Invalid(msg)) => assert!(msg.contains("bijection"), "{msg}"),
        other => panic!("expected Invalid(bijection), got {other:?}"),
    }
}

fn fnv_continue(h: u64, bytes: &[u8]) -> u64 {
    bytes.iter().fold(h, |h, &b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3))
}

/// Re-sign a hand-edited header so only the targeted structural check
/// can object.
fn resign_header(buf: &mut [u8]) {
    let hsum = fnv(&buf[..40]);
    buf[40..48].copy_from_slice(&hsum.to_le_bytes());
}

#[test]
fn width_flag_must_be_consistent_with_edge_count() {
    // a header claiming narrow offsets for >u32::MAX edges is rejected
    // before any section is read (no absurd allocation attempts); |V| is
    // forged large enough that the |V|² edge bound is not the check firing
    let g = CscBuilder::new(2).edges(&[(0, 1)]).build().unwrap();
    let mut buf = to_bytes(&g, None);
    buf[16..24].copy_from_slice(&100_000u64.to_le_bytes()); // nv
    buf[24..32].copy_from_slice(&(u32::MAX as u64 + 1).to_le_bytes()); // ne
    resign_header(&mut buf);
    match read_lgx(&mut &buf[..]) {
        Err(LgxError::Invalid(msg)) => assert!(msg.contains("u32::MAX"), "{msg}"),
        other => panic!("expected Invalid(width), got {other:?}"),
    }
}

#[test]
fn absurd_header_sizes_are_rejected_before_allocation() {
    let g = CscBuilder::new(2).edges(&[(0, 1)]).build().unwrap();
    // nv beyond u32 addressability
    let mut buf = to_bytes(&g, None);
    buf[16..24].copy_from_slice(&(u32::MAX as u64 + 1).to_le_bytes());
    resign_header(&mut buf);
    match read_lgx(&mut &buf[..]) {
        Err(LgxError::Invalid(msg)) => assert!(msg.contains("addressable"), "{msg}"),
        other => panic!("expected Invalid(vertex bound), got {other:?}"),
    }
    // ne beyond the |V|² structural maximum (wide flag set, so the width
    // check cannot be the one firing)
    let mut buf = to_bytes(&g, None);
    let flags = u32::from_le_bytes(buf[12..16].try_into().unwrap()) | 0b10; // wide
    buf[12..16].copy_from_slice(&flags.to_le_bytes());
    buf[24..32].copy_from_slice(&(1u64 << 40).to_le_bytes());
    resign_header(&mut buf);
    match read_lgx(&mut &buf[..]) {
        Err(LgxError::Invalid(msg)) => assert!(msg.contains("bound"), "{msg}"),
        other => panic!("expected Invalid(edge bound), got {other:?}"),
    }
}

#[test]
fn indptr_width_is_selected_at_the_boundary() {
    // the in-memory rule the format mirrors: |E| = u32::MAX narrows,
    // one more widens (file-level: small graphs carry the narrow flag,
    // verified by the roundtrip tests above keeping `is_narrow`)
    assert!(IndPtr::from_u64(vec![0, u32::MAX as u64]).is_narrow());
    assert!(!IndPtr::from_u64(vec![0, u32::MAX as u64 + 1]).is_narrow());
    // and a wide in-memory graph round-trips through the wide file path:
    // forge one by hand (tiny logical size, artificially wide offsets)
    let wide = CscGraph {
        indptr: IndPtr::U64(vec![0, 1, 2].into()),
        indices: vec![1, 0].into(),
        weights: None,
    };
    wide.validate().unwrap();
    let mut buf = Vec::new();
    write_lgx(&mut buf, &wide, None).unwrap();
    let (back, _) = read_lgx(&mut &buf[..]).unwrap();
    // widths may differ (logical equality is width-agnostic)…
    assert_eq!(back, wide);
    // …and the file preserved the writer's width choice exactly
    assert!(!back.indptr.is_narrow(), "wide flag must survive the round trip");
}

#[test]
fn failed_save_never_clobbers_an_existing_file() {
    let g = dense_graph();
    let path = std::env::temp_dir().join(format!("labor_lgx_keep_{}.lgx", std::process::id()));
    save_lgx(&path, &g, None).unwrap();
    // a save that fails validation (perm size mismatch) must leave the
    // existing file byte-for-byte intact, with no .tmp litter
    let wrong_perm = VertexPerm::identity(g.num_vertices() + 1);
    match save_lgx(&path, &g, Some(&wrong_perm)) {
        Err(LgxError::Invalid(msg)) => assert!(msg.contains("perm covers"), "{msg}"),
        other => panic!("expected Invalid(perm size), got {other:?}"),
    }
    let (back, perm) = load_lgx(&path).unwrap();
    assert_eq!(back, g);
    assert!(perm.is_none());
    let tmp = format!("{}.tmp", path.display());
    assert!(!std::path::Path::new(&tmp).exists(), "temp file left behind");
    std::fs::remove_file(&path).ok();
}

#[test]
fn load_errors_on_missing_file_are_io() {
    match load_lgx("/nonexistent/labor/never.lgx") {
        Err(LgxError::Io(_)) => {}
        other => panic!("expected Io, got {other:?}"),
    }
}

/// The optional parts section: a partition-major layout's
/// [`PartitionMap`] rides the file and comes back identical through every
/// loader — buffered, file, and zero-copy mapped — alongside the perm,
/// while the legacy two-tuple readers still parse (and drop) it.
#[test]
fn parts_section_roundtrips_through_every_loader() {
    let g = dense_graph();
    let assign = ldg_partition(&g, 3, 1.05);
    let (perm, parts) = partition_layout(&assign, 3).unwrap();
    let rg = perm.apply_to_graph(&g);
    let path = std::env::temp_dir().join(format!("labor_lgx_parts_{}.lgx", std::process::id()));
    save_lgx_full(&path, &rg, Some(&perm), Some(&parts)).unwrap();
    for (loader, got) in [
        ("load_lgx_full", load_lgx_full(&path).unwrap()),
        ("load_lgx_buffered_full", load_lgx_buffered_full(&path).unwrap()),
        ("load_lgx_mmap_full", load_lgx_mmap_full(&path).unwrap()),
    ] {
        let (back, back_perm, back_parts) = got;
        assert_eq!(back, rg, "{loader}: graph");
        assert_eq!(back_perm.as_ref(), Some(&perm), "{loader}: perm");
        assert_eq!(back_parts.as_ref(), Some(&parts), "{loader}: parts");
    }
    // legacy readers parse the same file and drop the section
    let (back, back_perm) = load_lgx(&path).unwrap();
    assert_eq!(back, rg);
    assert_eq!(back_perm.as_ref(), Some(&perm));
    std::fs::remove_file(&path).ok();
    // K=1 (the degenerate single partition) and parts-without-perm both
    // round-trip through the in-memory path
    for pm in [PartitionMap::single(rg.num_vertices()), parts.clone()] {
        let mut buf = Vec::new();
        write_lgx_full(&mut buf, &rg, None, Some(&pm)).unwrap();
        let (b, bp, bparts) = read_lgx_full(&mut &buf[..]).unwrap();
        assert_eq!(b, rg);
        assert_eq!(bp, None);
        assert_eq!(bparts.as_ref(), Some(&pm));
    }
    // a file written without parts loads as None through the full loaders
    let mut buf = Vec::new();
    write_lgx_full(&mut buf, &rg, Some(&perm), None).unwrap();
    let (_, _, none_parts) = read_lgx_full(&mut &buf[..]).unwrap();
    assert_eq!(none_parts, None);
}

/// The writer rejects a partition map that does not cover the graph, by
/// name, before any bytes hit the stream.
#[test]
fn mismatched_parts_are_rejected_at_write_time() {
    let g = weighted_graph();
    let wrong = PartitionMap::from_counts(&[2, 2]).unwrap(); // covers 4, graph has 6
    let mut buf = Vec::new();
    match write_lgx_full(&mut buf, &g, None, Some(&wrong)) {
        Err(LgxError::Invalid(msg)) => assert!(msg.contains("partition map covers"), "{msg}"),
        other => panic!("expected Invalid(coverage), got {other:?}"),
    }
    assert!(buf.is_empty(), "a rejected write must emit nothing");
}

/// Corrupting the parts section is caught by name in both loaders: a
/// flipped bounds byte fails the payload checksum (or bounds validation),
/// an absurd length prefix fails the pre-allocation bound, and a cut
/// inside the section is `Truncated("parts")`.
#[test]
fn parts_corruption_is_named() {
    // layout of this 3-vertex file: header @0, indptr (4 u32) @64,
    // indices (2 u32) @128, parts [3, 0, 2, 3] (4 u32) @192 — 256 B total
    let g = CscBuilder::new(3).edges(&[(0, 1), (1, 2)]).build().unwrap();
    let parts = PartitionMap::from_counts(&[2, 1]).unwrap();
    let mut buf = Vec::new();
    write_lgx_full(&mut buf, &g, None, Some(&parts)).unwrap();
    assert_eq!(buf.len(), 256, "layout drifted; fix the offsets in this test");
    let parts_off = 192usize;

    // 1. flipped bounds byte → checksum mismatch (never a wrong map)
    let mut c = buf.clone();
    c[parts_off + 8] ^= 0x01; // bounds[1]
    match read_lgx_full(&mut &c[..]) {
        Err(LgxError::ChecksumMismatch { expected, got }) => assert_ne!(expected, got),
        other => panic!("expected ChecksumMismatch, got {other:?}"),
    }

    // 2. absurd length prefix → named bound check, before any allocation
    //    is sized from it (fires ahead of the checksum pass)
    let mut c = buf.clone();
    c[parts_off..parts_off + 4].copy_from_slice(&200u32.to_le_bytes());
    for (which, res) in [
        ("buffered", read_lgx_full(&mut &c[..]).map(|_| ())),
        ("mapped", write_then_mmap(&c).map(|_| ())),
    ] {
        match res {
            Err(LgxError::Invalid(msg)) => {
                assert!(msg.contains("declares 200 bounds"), "{which}: {msg}")
            }
            other => panic!("{which}: expected Invalid(bounds count), got {other:?}"),
        }
    }

    // 3. checksums pass but the map does not cover the graph: re-sign the
    //    payload after forging bounds = [0, 2, 4] on a 3-vertex file
    let mut c = buf.clone();
    c[parts_off + 12..parts_off + 16].copy_from_slice(&4u32.to_le_bytes());
    let mut sum = 0xcbf2_9ce4_8422_2325u64;
    sum = fnv_continue(sum, &c[64..64 + 16]); // indptr (4 × u32)
    sum = fnv_continue(sum, &c[128..128 + 8]); // indices (2 × u32)
    sum = fnv_continue(sum, &c[parts_off..parts_off + 16]); // parts (4 × u32)
    c[32..40].copy_from_slice(&sum.to_le_bytes());
    resign_header(&mut c);
    match read_lgx_full(&mut &c[..]) {
        Err(LgxError::Invalid(msg)) => {
            assert!(msg.contains("covers 4 vertices"), "{msg}")
        }
        other => panic!("expected Invalid(coverage), got {other:?}"),
    }

    // 4. a cut inside the section names it
    let cut = &buf[..parts_off + 6];
    match read_lgx_full(&mut &cut[..]) {
        Err(LgxError::Truncated("parts")) => {}
        other => panic!("expected Truncated(parts), got {other:?}"),
    }
}

/// Round a corrupt byte buffer through a real file so the mapped loader
/// sees the same bytes the buffered loader was fed.
fn write_then_mmap(
    bytes: &[u8],
) -> Result<(CscGraph, Option<VertexPerm>, Option<PartitionMap>), LgxError> {
    let path = std::env::temp_dir().join(format!(
        "labor_lgx_corrupt_{}_{}.lgx",
        std::process::id(),
        bytes.len()
    ));
    std::fs::write(&path, bytes).unwrap();
    let out = load_lgx_mmap_full(&path);
    std::fs::remove_file(&path).ok();
    out
}
