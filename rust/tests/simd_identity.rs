//! The SIMD/prefetch contract: the vectorized feature gather and the
//! prefetch-hinted sampler walks are *accelerations only* — every result
//! is bit-identical to the scalar/unhinted path.
//!
//! The toggle under test is the same one `LABOR_NO_SIMD=1` flips at
//! startup ([`set_simd_enabled`]); it is process-global state, so every
//! test that flips it serializes on one mutex and restores the default
//! before releasing it.

use labor_gnn::coordinator::feature_store::{FeatureStore, TierModel};
use labor_gnn::graph::gen::{dc_sbm, DcSbmConfig};
use labor_gnn::graph::CscGraph;
use labor_gnn::rng::StreamRng;
use labor_gnn::sampler::{
    IterSpec, Mfg, MultiLayerSampler, SamplerKind, SamplerScratch, ScratchPool,
};
use labor_gnn::util::simd::{
    gather_rows_f32_scalar, gather_rows_f32_simd, set_simd_enabled,
};
use std::sync::Mutex;

/// Serializes every test that flips the process-global SIMD mode.
static SIMD_TOGGLE: Mutex<()> = Mutex::new(());

fn dense_graph() -> CscGraph {
    dc_sbm(&DcSbmConfig {
        num_vertices: 500,
        num_arcs: 30_000,
        num_communities: 4,
        homophily: 0.7,
        degree_exponent: 0.4,
        seed: 42,
    })
    .graph
}

fn every_kind() -> Vec<SamplerKind> {
    vec![
        SamplerKind::Neighbor,
        SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
        SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false },
        SamplerKind::Labor { iterations: IterSpec::Converge, layer_dependent: false },
        SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: true },
        SamplerKind::LaborSequential {
            iterations: IterSpec::Fixed(0),
            layer_dependent: false,
        },
        SamplerKind::Ladies { budgets: vec![60, 40] },
        SamplerKind::Pladies { budgets: vec![60, 40] },
    ]
}

fn assert_mfgs_identical(a: &Mfg, b: &Mfg, label: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{label}");
    for (l, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.seeds, lb.seeds, "{label} layer {l}: seeds");
        assert_eq!(la.inputs, lb.inputs, "{label} layer {l}: inputs");
        assert_eq!(la.edge_src, lb.edge_src, "{label} layer {l}: edge_src");
        assert_eq!(la.edge_dst, lb.edge_dst, "{label} layer {l}: edge_dst");
        assert_eq!(la.edge_weight, lb.edge_weight, "{label} layer {l}: edge_weight");
    }
}

/// The two row-gather kernels agree to the bit across awkward dims
/// (sub-vector, exact-vector, straddling, large) and duplicate/reversed
/// id lists, straight through the public dispatcher inputs.
#[test]
fn gather_kernels_are_bit_identical_across_dims() {
    let mut rng = StreamRng::new(0x51D);
    for dim in [1usize, 2, 3, 4, 5, 7, 8, 11, 16, 31, 64, 100, 256] {
        let rows = 257;
        let feats: Vec<f32> = (0..rows * dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let mut ids: Vec<u32> = (0..500).map(|_| rng.below(rows as u64) as u32).collect();
        ids.extend_from_slice(&[0, 0, (rows - 1) as u32, 0]); // dupes + edges
        let (mut a, mut b) = (Vec::new(), Vec::new());
        gather_rows_f32_simd(&feats, dim, &ids, &mut a);
        gather_rows_f32_scalar(&feats, dim, &ids, &mut b);
        assert_eq!(a.len(), b.len(), "dim {dim}");
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "dim {dim}, element {i}");
        }
    }
}

/// `FeatureStore::gather` returns bit-identical rows (and identical
/// accounting) with SIMD on and off.
#[test]
fn feature_store_gather_is_toggle_invariant() {
    let _guard = SIMD_TOGGLE.lock().unwrap();
    let mut rng = StreamRng::new(7);
    let (rows, dim) = (400usize, 33usize);
    let feats: Vec<f32> = (0..rows * dim).map(|_| rng.next_f32()).collect();
    let ids: Vec<u32> = (0..2_000).map(|_| rng.below(rows as u64) as u32).collect();
    let store = FeatureStore::new(feats, dim, TierModel::local());

    set_simd_enabled(true);
    let mut fast = Vec::new();
    store.gather(&ids, &mut fast);
    set_simd_enabled(false);
    let mut slow = Vec::new();
    store.gather(&ids, &mut slow);
    set_simd_enabled(true);

    assert_eq!(fast.len(), slow.len());
    for (i, (a, b)) in fast.iter().zip(&slow).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "row element {i}");
    }
}

/// Every sampler kind produces a bit-identical MFG with prefetch hints
/// enabled and disabled — the hints must not perturb visit order,
/// first-seen candidate numbering, or any sampled edge. Checked on the
/// sequential path and the sharded path (which has its own hinted walk).
#[test]
fn every_sampler_kind_is_prefetch_invariant() {
    let _guard = SIMD_TOGGLE.lock().unwrap();
    let g = dense_graph();
    let seeds: Vec<u32> = (0..64).map(|i| i * 7 % 500).collect();
    for kind in every_kind() {
        let label = kind.label();
        let sampler = MultiLayerSampler::new(kind, &[5, 5]);

        set_simd_enabled(true);
        let hinted = sampler.sample(&g, &seeds, 0xFEED, &mut SamplerScratch::new());
        let mut pool = ScratchPool::for_vertices(g.num_vertices(), 4);
        let hinted_sh = sampler.sample_sharded(&g, &seeds, 0xFEED, 4, &mut pool);

        set_simd_enabled(false);
        let plain = sampler.sample(&g, &seeds, 0xFEED, &mut SamplerScratch::new());
        let mut pool = ScratchPool::for_vertices(g.num_vertices(), 4);
        let plain_sh = sampler.sample_sharded(&g, &seeds, 0xFEED, 4, &mut pool);
        set_simd_enabled(true);

        assert_mfgs_identical(&hinted, &plain, &format!("{label} (sequential)"));
        assert_mfgs_identical(&hinted_sh, &plain_sh, &format!("{label} (sharded)"));
        assert_mfgs_identical(&hinted, &hinted_sh, &format!("{label} (seq vs sharded)"));
    }
}
