//! Microbenchmarks of the LABOR inner loops: the `c_s` solvers (sorted vs
//! the paper's iterative algorithm), the fixed-point step, and the hash
//! RNG. These are the L3 hot path (§Perf).

use labor_gnn::rng::{HashRng, StreamRng};
use labor_gnn::sampler::labor::{
    solve_cs_iterative, solve_cs_sorted, solve_cs_sorted_with, LaborLayerState,
};
use labor_gnn::sampler::{IterSpec, SamplerScratch};
use labor_gnn::util::timer::bench;

fn main() {
    println!("== c_s solver, heavy-tailed pi, k=10");
    for d in [16usize, 64, 256, 1024] {
        let mut rng = StreamRng::new(d as u64);
        let pi: Vec<f64> = (0..d).map(|_| (3.0 * rng.next_f64()).exp()).collect();
        let r = bench(10, 200, || {
            std::hint::black_box(solve_cs_sorted(&pi, 10.min(d - 1)));
        });
        r.report(&format!("solve_cs_sorted/d{d}"));
        let mut sort_buf = Vec::new();
        let mut recip_buf = Vec::new();
        let r = bench(10, 200, || {
            std::hint::black_box(solve_cs_sorted_with(
                &pi,
                10.min(d - 1),
                &mut sort_buf,
                &mut recip_buf,
            ));
        });
        r.report(&format!("solve_cs_sorted_scratch/d{d}"));
        let r = bench(10, 200, || {
            std::hint::black_box(solve_cs_iterative(&pi, 10.min(d - 1)));
        });
        r.report(&format!("solve_cs_iterative/d{d}"));
    }

    println!("\n== full layer state: build + optimize (flickr-sim-like synthetic)");
    let g = labor_gnn::graph::gen::dc_sbm(&labor_gnn::graph::gen::DcSbmConfig {
        num_vertices: 8920,
        num_arcs: 90_000,
        num_communities: 7,
        homophily: 0.7,
        degree_exponent: 0.85,
        seed: 1,
    })
    .graph;
    let seeds: Vec<u32> = (0..1024).collect();
    let r = bench(2, 20, || {
        std::hint::black_box(LaborLayerState::new(&g, &seeds, 10));
    });
    r.report("labor_state_build/b1024");
    // arena reuse: the same build with all buffers recycled between calls
    let mut scratch = SamplerScratch::for_vertices(g.num_vertices());
    let r = bench(2, 20, || {
        let st = LaborLayerState::new_in(&g, &seeds, 10, &mut scratch);
        std::hint::black_box(st.candidates.len());
        st.recycle(&mut scratch);
    });
    r.report("labor_state_build/b1024_warm_scratch");
    for iters in [0usize, 1, 3] {
        let r = bench(2, 10, || {
            let mut st = LaborLayerState::new(&g, &seeds, 10);
            st.optimize(IterSpec::Fixed(iters));
            std::hint::black_box(st.objective());
        });
        r.report(&format!("labor_optimize/i{iters}"));
    }

    println!("\n== hash rng");
    let rng = HashRng::new(7);
    let r = bench(10, 100, || {
        let mut acc = 0.0f64;
        for t in 0..100_000u64 {
            acc += rng.uniform(t);
        }
        std::hint::black_box(acc);
    });
    r.report("hash_rng/100k_uniforms");
}
