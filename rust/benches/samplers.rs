//! Sampler micro/throughput benchmarks (backs the it/s column of Table 2).
//!
//! `cargo bench --bench samplers` — uses the in-repo timing harness
//! (crates.io criterion is unavailable in the offline build; the harness
//! reports mean/p50/p95 and throughput per case).

use labor_gnn::data::Dataset;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind, SamplerScratch, ScratchPool};
use labor_gnn::util::timer::bench;

fn main() {
    let ds = Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset");
    let seeds: Vec<u32> = ds.splits.train[..1024.min(ds.splits.train.len())].to_vec();
    let fanouts = [10usize, 10, 10];
    let budgets = vec![3000, 5000, 6000];

    println!("== sampler throughput, flickr-sim scale 0.1, batch 1024, fanout 10, 3 layers");
    let cases: Vec<(&str, SamplerKind)> = vec![
        ("ns", SamplerKind::Neighbor),
        ("labor-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("labor-1", SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false }),
        ("labor-*", SamplerKind::Labor { iterations: IterSpec::Converge, layer_dependent: false }),
        (
            "labor-0-seq",
            SamplerKind::LaborSequential { iterations: IterSpec::Fixed(0), layer_dependent: false },
        ),
        ("ladies", SamplerKind::Ladies { budgets: budgets.clone() }),
        ("pladies", SamplerKind::Pladies { budgets }),
    ];
    for (name, kind) in cases {
        let sampler = MultiLayerSampler::new(kind, &fanouts);
        // steady-state: one warm scratch arena per case (as the pipeline
        // workers hold); compare with `samplers_fresh` below
        let mut scratch = SamplerScratch::new();
        let mut b = 0u64;
        let r = bench(2, 10, || {
            let mfg = sampler.sample(&ds.graph, &seeds, b, &mut scratch);
            std::hint::black_box(mfg.vertex_counts());
            b += 1;
        });
        r.report(&format!("sample_mfg/{name}"));
    }

    println!("\n== scratch reuse vs per-call allocation (labor-0, 3 layers)");
    {
        let sampler = MultiLayerSampler::new(
            SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
            &fanouts,
        );
        let mut scratch = SamplerScratch::new();
        let mut b = 0u64;
        let r = bench(2, 10, || {
            std::hint::black_box(sampler.sample(&ds.graph, &seeds, b, &mut scratch).edge_counts());
            b += 1;
        });
        r.report("labor0_3layer/warm_scratch");
        let mut b = 0u64;
        let r = bench(2, 10, || {
            std::hint::black_box(sampler.sample_fresh(&ds.graph, &seeds, b).edge_counts());
            b += 1;
        });
        r.report("labor0_3layer/fresh_scratch");
    }

    println!("\n== single-layer scaling with batch size (labor-0)");
    for bs in [128usize, 512, 2048] {
        let seeds: Vec<u32> = ds.splits.train[..bs.min(ds.splits.train.len())].to_vec();
        let sampler = MultiLayerSampler::new(
            SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
            &[10],
        );
        let mut scratch = SamplerScratch::new();
        let mut b = 0u64;
        let r = bench(2, 20, || {
            std::hint::black_box(sampler.sample(&ds.graph, &seeds, b, &mut scratch).edge_counts());
            b += 1;
        });
        r.report(&format!("labor0_1layer/batch{bs}"));
    }

    // intra-batch shard scaling: the large-batch regime, where one batch
    // dominates the epoch and only seed sharding can use more cores;
    // output is bit-identical across shard counts (tests/parallel_identity)
    println!("\n== sharded full-MFG sampling, large batch (shards=1 is sequential)");
    let big: Vec<u32> = ds.splits.train[..4096.min(ds.splits.train.len())].to_vec();
    for (name, kind) in [
        ("labor-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("labor-1", SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false }),
        ("ns", SamplerKind::Neighbor),
    ] {
        let sampler = MultiLayerSampler::new(kind, &fanouts);
        for shards in [1usize, 2, 4, 8] {
            let mut pool = ScratchPool::for_vertices(ds.graph.num_vertices(), shards);
            let mut b = 0u64;
            let r = bench(2, 8, || {
                let mfg = sampler.sample_sharded(&ds.graph, &big, b, shards, &mut pool);
                std::hint::black_box(mfg.vertex_counts());
                b += 1;
            });
            r.report(&format!("sharded_mfg/{name}/shards{shards}"));
        }
    }
}
