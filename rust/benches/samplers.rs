//! Sampler micro/throughput benchmarks (backs the it/s column of Table 2)
//! plus the graph-engine locality sweep.
//!
//! `cargo bench --bench samplers` — uses the in-repo timing harness
//! (crates.io criterion is unavailable in the offline build; the harness
//! reports mean/p50/p95 and throughput per case).
//! `cargo bench --bench samplers -- --smoke` — tiny iteration counts (CI).
//!
//! The final section measures the `graph::compact` engine: sampling
//! throughput on the original vs the degree-ordered relabeled layout,
//! feature-gather time through a bitmap vs a prefix `DegreeOrderedCache`
//! (with a hit-accounting equivalence check), and `.lgx` zero-copy load
//! time vs the legacy parse-and-rebuild binary and a text edge list. The
//! results are written to `BENCH_graph.json` (asserted by ci.sh) — this is
//! the paper's §4.1 cost model made measurable: LABOR shrinks *how many*
//! vertices a batch touches, the layout shrinks *how much* each touch
//! costs.

use labor_gnn::coordinator::cache::{DegreeOrderedCache, FeatureCache};
use labor_gnn::coordinator::feature_store::{FeatureStore, TierModel};
use labor_gnn::data::Dataset;
use labor_gnn::graph::io as graph_io;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind, SamplerScratch, ScratchPool};
use labor_gnn::util::json::Json;
use labor_gnn::util::timer::bench;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warm, iters) = if smoke { (1usize, 2usize) } else { (2, 10) };
    let ds = Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset");
    let seeds: Vec<u32> = ds.splits.train[..1024.min(ds.splits.train.len())].to_vec();
    let fanouts = [10usize, 10, 10];
    let budgets = vec![3000, 5000, 6000];

    println!("== sampler throughput, flickr-sim scale 0.1, batch 1024, fanout 10, 3 layers");
    let cases: Vec<(&str, SamplerKind)> = vec![
        ("ns", SamplerKind::Neighbor),
        ("labor-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("labor-1", SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false }),
        ("labor-*", SamplerKind::Labor { iterations: IterSpec::Converge, layer_dependent: false }),
        (
            "labor-0-seq",
            SamplerKind::LaborSequential { iterations: IterSpec::Fixed(0), layer_dependent: false },
        ),
        ("ladies", SamplerKind::Ladies { budgets: budgets.clone() }),
        ("pladies", SamplerKind::Pladies { budgets }),
    ];
    for (name, kind) in cases {
        let sampler = MultiLayerSampler::new(kind, &fanouts);
        // steady-state: one warm scratch arena per case (as the pipeline
        // workers hold); compare with `samplers_fresh` below
        let mut scratch = SamplerScratch::new();
        let mut b = 0u64;
        let r = bench(warm, iters, || {
            let mfg = sampler.sample(&ds.graph, &seeds, b, &mut scratch);
            std::hint::black_box(mfg.vertex_counts());
            b += 1;
        });
        r.report(&format!("sample_mfg/{name}"));
    }

    println!("\n== scratch reuse vs per-call allocation (labor-0, 3 layers)");
    {
        let sampler = MultiLayerSampler::new(
            SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
            &fanouts,
        );
        let mut scratch = SamplerScratch::new();
        let mut b = 0u64;
        let r = bench(warm, iters, || {
            std::hint::black_box(sampler.sample(&ds.graph, &seeds, b, &mut scratch).edge_counts());
            b += 1;
        });
        r.report("labor0_3layer/warm_scratch");
        let mut b = 0u64;
        let r = bench(warm, iters, || {
            std::hint::black_box(sampler.sample_fresh(&ds.graph, &seeds, b).edge_counts());
            b += 1;
        });
        r.report("labor0_3layer/fresh_scratch");
    }

    println!("\n== single-layer scaling with batch size (labor-0)");
    for bs in [128usize, 512, 2048] {
        let seeds: Vec<u32> = ds.splits.train[..bs.min(ds.splits.train.len())].to_vec();
        let sampler = MultiLayerSampler::new(
            SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
            &[10],
        );
        let mut scratch = SamplerScratch::new();
        let mut b = 0u64;
        let r = bench(warm, iters.max(4), || {
            std::hint::black_box(sampler.sample(&ds.graph, &seeds, b, &mut scratch).edge_counts());
            b += 1;
        });
        r.report(&format!("labor0_1layer/batch{bs}"));
    }

    // intra-batch shard scaling: the large-batch regime, where one batch
    // dominates the epoch and only seed sharding can use more cores;
    // output is bit-identical across shard counts (tests/parallel_identity)
    println!("\n== sharded full-MFG sampling, large batch (shards=1 is sequential)");
    let big: Vec<u32> = ds.splits.train[..4096.min(ds.splits.train.len())].to_vec();
    for (name, kind) in [
        ("labor-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("labor-1", SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false }),
        ("ns", SamplerKind::Neighbor),
    ] {
        let sampler = MultiLayerSampler::new(kind, &fanouts);
        for shards in [1usize, 2, 4, 8] {
            let mut pool = ScratchPool::for_vertices(ds.graph.num_vertices(), shards);
            let mut b = 0u64;
            let r = bench(warm, if smoke { 2 } else { 8 }, || {
                let mfg = sampler.sample_sharded(&ds.graph, &big, b, shards, &mut pool);
                std::hint::black_box(mfg.vertex_counts());
                b += 1;
            });
            r.report(&format!("sharded_mfg/{name}/shards{shards}"));
        }
    }

    // -- graph engine: original vs degree-ordered relabeled layout -------
    // Same dataset, same samplers, two physical layouts of the same
    // logical graph. The relabeled runs use forward-mapped seeds, so the
    // workload is the isomorphic image of the original one.
    println!("\n== graph engine: degree-ordered relabeling locality sweep");
    let (rds, perm) = ds.relabel_by_degree();
    assert!(rds.graph.is_degree_ordered());
    let seeds_rel: Vec<u32> = seeds.iter().map(|&v| perm.to_new(v)).collect();
    let mut relabel_series = Vec::new();
    for (name, kind) in [
        ("ns", SamplerKind::Neighbor),
        ("labor-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("labor-1", SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false }),
    ] {
        let sampler = MultiLayerSampler::new(kind, &fanouts);
        for (layout, g, s) in
            [("original", &ds.graph, &seeds), ("relabeled", &rds.graph, &seeds_rel)]
        {
            let mut scratch = SamplerScratch::for_vertices(g.num_vertices());
            let mut b = 0u64;
            let r = bench(warm, iters, || {
                let mfg = sampler.sample(g, s, b, &mut scratch);
                std::hint::black_box(mfg.edge_counts_iter().sum::<usize>());
                b += 1;
            });
            r.report(&format!("relabel_mfg/{name}/{layout}"));
            relabel_series.push(Json::obj(vec![
                ("sampler", Json::Str(name.into())),
                ("layout", Json::Str(layout.into())),
                ("batches_per_s", Json::Num(r.throughput())),
            ]));
        }
    }

    // -- gather sweep: bitmap residency vs the id<k prefix fast path -----
    // The same top-10% degree policy over both layouts. Hit accounting is
    // REQUIRED to be identical (same policy, ids mapped); the prefix
    // representation only changes what a lookup costs.
    let dim = ds.spec.num_features;
    let cache_rows = ds.graph.num_vertices() / 10;
    let orig_cache = Arc::new(DegreeOrderedCache::new(&ds.graph, cache_rows));
    let rel_cache = Arc::new(DegreeOrderedCache::new(&rds.graph, cache_rows));
    assert!(!orig_cache.is_prefix() && rel_cache.is_prefix());
    let orig_store = Arc::new(
        FeatureStore::new(ds.features.clone(), dim, TierModel::local())
            .with_cache(orig_cache.clone() as Arc<dyn FeatureCache>),
    );
    let rel_store = Arc::new(
        FeatureStore::new(rds.features.clone(), dim, TierModel::local())
            .with_cache(rel_cache.clone() as Arc<dyn FeatureCache>),
    );
    assert_eq!(rel_store.cache_prefix_rows(), Some(cache_rows));
    // one deepest-layer id set, gathered through both stores (mapped ids)
    let sampler = MultiLayerSampler::new(
        SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
        &fanouts,
    );
    let mfg = sampler.sample_fresh(&ds.graph, &seeds, 7);
    let ids_orig: Vec<u32> = mfg.feature_vertices().to_vec();
    let ids_rel: Vec<u32> = ids_orig.iter().map(|&v| perm.to_new(v)).collect();
    let mut out = Vec::new();
    let gather_iters = if smoke { 3 } else { 30 };
    let t0 = Instant::now();
    for _ in 0..gather_iters {
        orig_store.gather(&ids_orig, &mut out);
        std::hint::black_box(out.len());
    }
    let t_orig = t0.elapsed();
    let t0 = Instant::now();
    for _ in 0..gather_iters {
        rel_store.gather(&ids_rel, &mut out);
        std::hint::black_box(out.len());
    }
    let t_rel = t0.elapsed();
    assert_eq!(
        orig_store.cache_hits(),
        rel_store.cache_hits(),
        "hit accounting must be layout-independent"
    );
    assert_eq!(orig_store.bytes_gathered(), rel_store.bytes_gathered());
    println!(
        "gather {} rows x{gather_iters}: bitmap {:.2?}, prefix {:.2?} (hit rate {:.1}%, equal)",
        ids_orig.len(),
        t_orig,
        t_rel,
        orig_store.hit_rate() * 100.0
    );

    // -- .lgx zero-copy load vs parse-and-rebuild formats ----------------
    let dir = std::env::temp_dir().join(format!("labor_bench_graph_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let lgx_path = dir.join("g.lgx");
    let legacy_path = dir.join("g.bin");
    let text_path = dir.join("g.txt");
    graph_io::save_lgx(&lgx_path, &rds.graph, Some(&perm)).expect("save lgx");
    graph_io::save_graph(&legacy_path, &rds.graph).expect("save legacy");
    graph_io::save_edgelist(&text_path, &rds.graph).expect("save edgelist");
    let time_load = |f: &mut dyn FnMut()| -> f64 {
        let n = if smoke { 2 } else { 5 };
        let t0 = Instant::now();
        for _ in 0..n {
            f();
        }
        t0.elapsed().as_secs_f64() / n as f64
    };
    let t_lgx = time_load(&mut || {
        let (g, p) = graph_io::load_lgx_buffered(&lgx_path).expect("load lgx");
        assert!(p.is_some());
        std::hint::black_box(g.num_edges());
    });
    let mmap_available = graph_io::mmap_enabled();
    let t_mmap = if mmap_available {
        time_load(&mut || {
            let (g, p) = graph_io::load_lgx_mmap(&lgx_path).expect("load lgx mmap");
            assert!(g.is_mapped(), "mmap load must borrow the mapping");
            assert!(p.is_some());
            std::hint::black_box(g.num_edges());
        })
    } else {
        0.0
    };
    let t_legacy = time_load(&mut || {
        std::hint::black_box(graph_io::load_graph(&legacy_path).expect("load legacy").num_edges());
    });
    let t_text = time_load(&mut || {
        std::hint::black_box(graph_io::load_edgelist(&text_path).expect("load text").num_edges());
    });
    // correctness: all load paths agree, and the mapped loader is
    // bit-identical to the buffered one
    let (g_lgx, p_lgx) = graph_io::load_lgx_buffered(&lgx_path).unwrap();
    assert_eq!(g_lgx, rds.graph);
    assert_eq!(p_lgx.as_ref(), Some(&perm));
    if mmap_available {
        let (g_map, p_map) = graph_io::load_lgx_mmap(&lgx_path).unwrap();
        assert!(g_map.is_mapped());
        assert_eq!(g_map, g_lgx, "mmap load differs from buffered load");
        assert_eq!(p_map, p_lgx, "mmap perm differs from buffered perm");
    }
    assert_eq!(graph_io::load_graph(&legacy_path).unwrap(), rds.graph);
    assert_eq!(graph_io::load_edgelist(&text_path).unwrap(), rds.graph);
    let fsize = |p: &std::path::Path| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
    println!(
        "load {} edges: .lgx mmap {:.3} ms, .lgx buffered {:.3} ms, legacy {:.3} ms, \
         text {:.3} ms ({:.1}x text/.lgx)",
        rds.graph.num_edges(),
        t_mmap * 1e3,
        t_lgx * 1e3,
        t_legacy * 1e3,
        t_text * 1e3,
        t_text / t_lgx.max(1e-12)
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("graph".into())),
        ("dataset", Json::Str("flickr-sim".into())),
        ("scale", Json::Num(0.1)),
        ("smoke", Json::Bool(smoke)),
        ("fanouts", Json::Arr(vec![Json::Num(10.0); 3])),
        ("batch_size", Json::Num(seeds.len() as f64)),
        ("relabel_sampling", Json::Arr(relabel_series)),
        (
            "gather",
            Json::obj(vec![
                ("rows", Json::Num(ids_orig.len() as f64)),
                ("dim", Json::Num(dim as f64)),
                ("iters", Json::Num(gather_iters as f64)),
                ("cache_rows", Json::Num(cache_rows as f64)),
                ("bitmap_s", Json::Num(t_orig.as_secs_f64())),
                ("prefix_s", Json::Num(t_rel.as_secs_f64())),
                ("hit_rate", Json::Num(orig_store.hit_rate())),
                ("hits_equal", Json::Bool(true)),
                (
                    "prefix_rows",
                    Json::Num(rel_store.cache_prefix_rows().unwrap_or(0) as f64),
                ),
            ]),
        ),
        (
            "formats",
            Json::obj(vec![
                ("edges", Json::Num(rds.graph.num_edges() as f64)),
                ("lgx_bytes", Json::Num(fsize(&lgx_path) as f64)),
                ("legacy_bytes", Json::Num(fsize(&legacy_path) as f64)),
                ("text_bytes", Json::Num(fsize(&text_path) as f64)),
                ("lgx_load_s", Json::Num(t_lgx)),
                ("lgx_mmap_load_s", Json::Num(t_mmap)),
                ("mmap_available", Json::Bool(mmap_available)),
                ("legacy_load_s", Json::Num(t_legacy)),
                ("text_load_s", Json::Num(t_text)),
                ("text_over_lgx", Json::Num(t_text / t_lgx.max(1e-12))),
            ]),
        ),
    ]);
    std::fs::write("BENCH_graph.json", format!("{report}\n")).expect("write BENCH_graph.json");
    println!("wrote BENCH_graph.json");
    std::fs::remove_dir_all(&dir).ok();
}
