//! Execution-engine microbenchmarks: what the hot-path machinery buys.
//!
//! Three sections, one headline number each, all identity-checked against
//! the path they replace before any timing is trusted:
//!
//! 1. `pool_speedup` — sharded LABOR-0 sampling through the persistent
//!    worker pool (`sampler::pool`) vs the same shards on freshly scoped
//!    spawn-per-call threads (`LABOR_NO_POOL` mode). Same shard plan,
//!    same bits; the delta is pure thread-creation overhead.
//! 2. `plan_speedup` — weighted LABOR (A.7) with precomputed static-π
//!    `c*` tables (`sampler::plan`) vs the live per-batch solver. The
//!    plan build itself is timed separately (`plan_build_ms`) — it is
//!    paid once, off the sampling path.
//! 3. `memo_hit_rate` — a Zipf request stream (popularity = degree rank)
//!    through the hot-vertex sample memo (`sampler::memo`) within one
//!    variate epoch, plus the warm-over-live speedup.
//!
//! Results go to `BENCH_hotpath.json` (asserted + printed by ci.sh).
//!
//! `cargo bench --bench hotpath` — full run.
//! `cargo bench --bench hotpath -- --smoke` — tiny sizes.

use labor_gnn::data::Dataset;
use labor_gnn::graph::builder::CscBuilder;
use labor_gnn::graph::compact::degree_order;
use labor_gnn::graph::gen::{zipf_requests, ZipfRequestConfig};
use labor_gnn::graph::CscGraph;
use labor_gnn::rng::StreamRng;
use labor_gnn::sampler::pool::set_pool_enabled;
use labor_gnn::sampler::weighted::WeightedLaborSampler;
use labor_gnn::sampler::{
    IterSpec, LayerSampler, Mfg, MultiLayerSampler, SampleCtx, SampleMemo, SamplePlan,
    SamplerKind, SamplerScratch, ScratchPool,
};
use labor_gnn::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

fn assert_mfg_eq(a: &Mfg, b: &Mfg, what: &str) {
    assert_eq!(a.layers.len(), b.layers.len(), "{what}: layer count");
    for (l, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.inputs, lb.inputs, "{what} layer {l}: inputs");
        assert_eq!(la.edge_src, lb.edge_src, "{what} layer {l}: edge_src");
        assert_eq!(la.edge_dst, lb.edge_dst, "{what} layer {l}: edge_dst");
        let wa: Vec<u32> = la.edge_weight.iter().map(|w| w.to_bits()).collect();
        let wb: Vec<u32> = lb.edge_weight.iter().map(|w| w.to_bits()).collect();
        assert_eq!(wa, wb, "{what} layer {l}: edge_weight bits");
    }
}

fn batches(nv: u32, count: usize, size: u32, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = StreamRng::new(seed);
    (0..count)
        .map(|_| {
            let start = rng.below(nv as u64) as u32;
            let mut s: Vec<u32> = (0..size).map(|i| (start + i * 3) % nv).collect();
            s.sort_unstable();
            s.dedup();
            s
        })
        .collect()
}

fn weighted_graph(nv: u32, seed: u64) -> CscGraph {
    let mut rng = StreamRng::new(seed);
    let mut b = CscBuilder::new(nv as usize);
    for s in 0..nv {
        let deg = 3 + rng.below(25) as usize;
        let mut used = std::collections::HashSet::new();
        for _ in 0..deg {
            let t = rng.below(nv as u64) as u32;
            if t != s && used.insert(t) {
                b.weighted_edge(t, s, 0.1 + rng.next_f32() * 2.0);
            }
        }
    }
    b.build().unwrap()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // == 1. persistent pool vs scoped spawns ==
    let ds = Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset");
    let g = &ds.graph;
    let nv = g.num_vertices() as u32;
    let sampler = MultiLayerSampler::new(
        SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
        &[10, 10],
    );
    let shards = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(8);
    let (rounds, nbatch, bsize) = if smoke { (2, 4, 256) } else { (5, 20, 1024) };
    let pool_batches = batches(nv, nbatch, bsize, 0xB00);
    let mut pool = ScratchPool::new();

    // identity first: pooled ≡ spawned on the first batch
    set_pool_enabled(true);
    let a = sampler.sample_sharded(g, &pool_batches[0], 1, shards, &mut pool);
    set_pool_enabled(false);
    let b = sampler.sample_sharded(g, &pool_batches[0], 1, shards, &mut pool);
    assert_mfg_eq(&a, &b, "pool vs spawn");

    let mut time_mode = |pooled: bool| {
        set_pool_enabled(pooled);
        // warm up thread state + arenas outside the timed region
        sampler.sample_sharded(g, &pool_batches[0], 0, shards, &mut pool);
        let t0 = Instant::now();
        for r in 0..rounds {
            for (i, seeds) in pool_batches.iter().enumerate() {
                sampler.sample_sharded(g, seeds, (r * nbatch + i) as u64, shards, &mut pool);
            }
        }
        t0.elapsed().as_secs_f64()
    };
    let t_spawn = time_mode(false);
    let t_pool = time_mode(true);
    set_pool_enabled(true);
    let pool_speedup = t_spawn / t_pool;
    let per_batch_us = |t: f64| t / (rounds * nbatch) as f64 * 1e6;
    println!(
        "pool:  {shards} shards, {} batches x {} seeds: spawn {:.1} us/batch, \
         pool {:.1} us/batch, speedup {pool_speedup:.2}x",
        rounds * nbatch,
        bsize,
        per_batch_us(t_spawn),
        per_batch_us(t_pool),
    );

    // == 2. static-π plan vs live weighted solver ==
    let wg = weighted_graph(if smoke { 2_000 } else { 20_000 }, 0xA7);
    let wnv = wg.num_vertices() as u32;
    let t0 = Instant::now();
    let plan = Arc::new(SamplePlan::build(&wg, &[10]));
    let plan_build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let live = WeightedLaborSampler { fanouts: vec![10], iterations: IterSpec::Fixed(0), plan: None };
    let planned = WeightedLaborSampler {
        fanouts: vec![10],
        iterations: IterSpec::Fixed(0),
        plan: Some(plan),
    };
    let plan_batches = batches(wnv, nbatch, bsize, 0x914);
    let mut s1 = SamplerScratch::new();
    let mut s2 = SamplerScratch::new();
    let ctx0 = SampleCtx::new(1, 0);
    let a = live.sample_layer(&wg, &plan_batches[0], ctx0, &mut s1);
    let b = planned.sample_layer(&wg, &plan_batches[0], ctx0, &mut s2);
    assert_eq!(a.edge_src, b.edge_src, "plan vs live: edge_src");
    let wa: Vec<u32> = a.edge_weight.iter().map(|w| w.to_bits()).collect();
    let wb: Vec<u32> = b.edge_weight.iter().map(|w| w.to_bits()).collect();
    assert_eq!(wa, wb, "plan vs live: weight bits");

    let time_sampler = |s: &WeightedLaborSampler, scratch: &mut SamplerScratch| {
        let t0 = Instant::now();
        for r in 0..rounds {
            for (i, seeds) in plan_batches.iter().enumerate() {
                let ctx = SampleCtx::new((r * nbatch + i) as u64, 0);
                s.sample_layer(&wg, seeds, ctx, scratch);
            }
        }
        t0.elapsed().as_secs_f64()
    };
    let t_live = time_sampler(&live, &mut s1);
    let t_planned = time_sampler(&planned, &mut s2);
    let plan_speedup = t_live / t_planned;
    println!(
        "plan:  weighted labor-0 on {wnv} vertices: live {:.1} us/batch, \
         planned {:.1} us/batch, speedup {plan_speedup:.2}x (build {plan_build_ms:.1} ms, once)",
        per_batch_us(t_live),
        per_batch_us(t_planned),
    );

    // == 3. hot-vertex memo under a Zipf stream ==
    let order = degree_order(g);
    let stream = zipf_requests(&ZipfRequestConfig {
        num_ids: g.num_vertices(),
        exponent: 1.0,
        num_requests: if smoke { 1_024 } else { 16_384 },
        rate_hz: 1.0,
        seed: 42,
    });
    let fanouts = [10usize, 10];
    let memo_bsize = 256;
    let memo_batches: Vec<Vec<u32>> = stream
        .seeds
        .chunks(memo_bsize)
        .map(|c| {
            let mut s: Vec<u32> = c.iter().map(|&r| order[r as usize]).collect();
            s.sort_unstable();
            s.dedup();
            s
        })
        .collect();
    let mut memo = SampleMemo::new(g.num_vertices());
    let mut scratch = SamplerScratch::new();
    let epoch = 0xE0;
    // identity against the live multi-layer sampler, then a timed warm
    // replay of the whole stream within the same variate epoch
    for seeds in &memo_batches {
        let want = sampler.sample_with_cap(g, seeds, epoch, None, &mut s1);
        let got = memo.sample(g, &fanouts, None, seeds, epoch, &mut scratch);
        assert_mfg_eq(&got, &want, "memo vs live");
    }
    memo.take_counters();
    let t0 = Instant::now();
    for seeds in &memo_batches {
        memo.sample(g, &fanouts, None, seeds, epoch, &mut scratch);
    }
    let t_memo = t0.elapsed().as_secs_f64();
    let (hits, misses) = memo.take_counters();
    let memo_hit_rate = hits as f64 / (hits + misses).max(1) as f64;
    let t0 = Instant::now();
    for seeds in &memo_batches {
        sampler.sample_with_cap(g, seeds, epoch, None, &mut s1);
    }
    let t_fresh = t0.elapsed().as_secs_f64();
    let memo_speedup = t_fresh / t_memo;
    assert!(memo_hit_rate > 0.5, "warm same-epoch replay must mostly hit, got {memo_hit_rate}");
    println!(
        "memo:  zipf(1.0) x {} requests, warm epoch: hit rate {memo_hit_rate:.3} \
         ({hits} hits / {misses} misses), warm-vs-live speedup {memo_speedup:.2}x",
        stream.seeds.len(),
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("smoke", Json::Bool(smoke)),
        ("shards", Json::Num(shards as f64)),
        ("pool_speedup", Json::Num(pool_speedup)),
        ("plan_speedup", Json::Num(plan_speedup)),
        ("plan_build_ms", Json::Num(plan_build_ms)),
        ("memo_hit_rate", Json::Num(memo_hit_rate)),
        ("memo_speedup", Json::Num(memo_speedup)),
    ]);
    std::fs::write("BENCH_hotpath.json", format!("{report}\n"))
        .expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json");
}
