//! Partition-engine benchmarks: what locality-ordered shards buy.
//!
//! Four sections, one headline number each, all identity-checked against
//! the unpartitioned path before any timing is trusted:
//!
//! 1. `cut_fraction_*` — edge-cut quality of the greedy LDG streaming
//!    partitioner vs the degree-balanced contiguous fallback vs random
//!    assignment, at the same partition count and balance slack.
//! 2. `local_hit_*` — fraction of gathered feature rows served from the
//!    gather's home partition when LABOR-0 mini-batch frontiers are
//!    routed through the partition-split store ([`PartitionedStore`]).
//!    Asserted in-bench: LDG must beat random — that gap *is* the value
//!    of locality-aware placement.
//! 3. `priced_gather_*` — the same gathers priced under the remote tier
//!    (per-hop latency + bandwidth on cross-partition rows): LDG vs
//!    random placement vs the unpartitioned baseline where every row
//!    lives behind the remote tier (one parameter server).
//! 4. `remote_amplification_ns_over_labor0` — NS remote bytes per batch
//!    over LABOR-0's, same seeds, same placement. The paper's frontier
//!    shrinkage (§3.2) measured as cross-partition traffic: the frontier
//!    *is* the traffic, so smaller unique-vertex sets are fewer remote
//!    bytes.
//!
//! Results go to `BENCH_partition.json` (asserted + printed by ci.sh).
//!
//! `cargo bench --bench partition` — full run.
//! `cargo bench --bench partition -- --smoke` — tiny sizes.

use labor_gnn::coordinator::{FeatureStore, PartitionedStore, TierModel};
use labor_gnn::data::Dataset;
use labor_gnn::graph::partition::{
    contiguous_partition, edge_cut, ldg_partition, partition_layout, random_partition,
};
use labor_gnn::graph::PartitionMap;
use labor_gnn::rng::StreamRng;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind, ScratchPool};
use labor_gnn::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

fn batches(nv: u32, count: usize, size: u32, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = StreamRng::new(seed);
    (0..count)
        .map(|_| {
            let start = rng.below(nv as u64) as u32;
            let mut s: Vec<u32> = (0..size).map(|i| (start + i * 7) % nv).collect();
            s.sort_unstable();
            s.dedup();
            s
        })
        .collect()
}

/// Route every batch's deepest-layer frontier through `ps`, gathering
/// from the frontier's home partition. Returns wall time; locality lands
/// in the store's counters.
fn route_batches(ps: &PartitionedStore, frontiers: &[Vec<u32>], out: &mut Vec<f32>) -> f64 {
    let t0 = Instant::now();
    for ids in frontiers {
        let home = ps.home_for(ids);
        ps.gather_from(home, ids, out);
    }
    t0.elapsed().as_secs_f64()
}

/// Analytic priced time of the unpartitioned baseline: every row sits
/// behind the remote tier (one parameter server), one hop per gather.
fn unpartitioned_priced_us(tier: TierModel, gathers: u64, rows: u64, row_bytes: u64) -> f64 {
    let latency = tier.request_latency.as_secs_f64() * gathers as f64;
    let transfer = if tier.bandwidth_bps.is_infinite() {
        0.0
    } else {
        (rows * row_bytes) as f64 / tier.bandwidth_bps
    };
    (latency + transfer) * 1e6
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ds = Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset");
    let g = &ds.graph;
    let nv = g.num_vertices();
    let k = if smoke { 4 } else { 8 };
    let slack = 1.05;
    let (nbatch, bsize) = if smoke { (8, 256) } else { (40, 1024) };

    // == 1. edge-cut quality ==
    let strategies: Vec<(&str, Vec<u32>)> = vec![
        ("ldg", ldg_partition(g, k, slack)),
        ("contiguous", contiguous_partition(g, k)),
        ("random", random_partition(nv, k, 0xC07)),
    ];
    let mut cut_fraction = std::collections::HashMap::new();
    for (name, assign) in &strategies {
        let (cut, total) = edge_cut(g, assign);
        let frac = cut as f64 / total.max(1) as f64;
        cut_fraction.insert(*name, frac);
        println!("cut:   {name:<10} K={k}: {cut}/{total} cut ({frac:.3})");
    }
    assert!(
        cut_fraction["ldg"] < cut_fraction["random"],
        "LDG must cut fewer edges than random placement"
    );

    // == 2 + 3. locality + priced gathers through the split store ==
    let sampler = MultiLayerSampler::new(
        SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
        &[10, 10],
    );
    let tier = TierModel::remote();
    let mut local_hit = std::collections::HashMap::new();
    let mut priced_us = std::collections::HashMap::new();
    let mut labor_frontier_rows = 0u64;
    for (name, assign) in &strategies {
        let (perm, map) = partition_layout(assign, k).expect("layout");
        let pds = ds.relabel_with(&perm);
        let map = Arc::new(map);
        let pg = &pds.graph;
        let dim = pds.num_features();
        let ps = PartitionedStore::split(&pds.features, dim, map.clone(), tier);

        // frontiers: LABOR-0 deepest-layer inputs on the relabeled graph,
        // sampled partition-aware (map attached) — identity-checked
        // against the fresh unpartitioned sampler first
        let mut pool = ScratchPool::new();
        pool.set_partition_map(Some(map.clone()));
        let seed_batches = batches(nv as u32, nbatch, bsize, 0x5EED);
        let frontiers: Vec<Vec<u32>> = seed_batches
            .iter()
            .enumerate()
            .map(|(i, seeds)| {
                let mfg = sampler.sample_sharded(pg, seeds, i as u64, 4, &mut pool);
                if i == 0 {
                    let fresh = sampler.sample_fresh(pg, seeds, i as u64);
                    assert_eq!(
                        mfg.feature_vertices(),
                        fresh.feature_vertices(),
                        "{name}: partition-aware sampling drifted from fresh"
                    );
                }
                mfg.feature_vertices().to_vec()
            })
            .collect();

        // identity: split-store bytes == flat-store bytes on batch 0
        let flat = FeatureStore::new(pds.features.clone(), dim, TierModel::local());
        let (mut want, mut got) = (Vec::new(), Vec::new());
        flat.gather(&frontiers[0], &mut want);
        ps.gather_from(ps.home_for(&frontiers[0]), &frontiers[0], &mut got);
        let wb: Vec<u32> = want.iter().map(|f| f.to_bits()).collect();
        let gb: Vec<u32> = got.iter().map(|f| f.to_bits()).collect();
        assert_eq!(wb, gb, "{name}: split store changed gathered bytes");
        ps.reset_counters();

        let mut out = Vec::new();
        let wall = route_batches(&ps, &frontiers, &mut out);
        let snap = ps.snapshot();
        let hit = ps.local_hit_fraction();
        let priced = ps.priced_time(tier).as_secs_f64() * 1e6;
        local_hit.insert(*name, hit);
        priced_us.insert(*name, priced / nbatch as f64);
        if *name == "ldg" {
            labor_frontier_rows = snap.local_rows + snap.remote_rows;
        }
        println!(
            "local: {name:<10} K={k}: hit {hit:.3} ({} local / {} remote rows), \
             priced {:.1} us/batch (wall {:.1} us/batch)",
            snap.local_rows,
            snap.remote_rows,
            priced / nbatch as f64,
            wall * 1e6 / nbatch as f64,
        );
    }
    assert!(
        local_hit["ldg"] > local_hit["random"],
        "LDG local-hit {:.3} must beat random {:.3} — locality placement is the point",
        local_hit["ldg"],
        local_hit["random"]
    );
    let unpart_us = unpartitioned_priced_us(
        tier,
        nbatch as u64,
        labor_frontier_rows,
        (ds.num_features() * 4) as u64,
    ) / nbatch as f64;
    println!("price: unpartitioned (all rows remote): {unpart_us:.1} us/batch");

    // == 4. NS vs LABOR-0 remote-byte amplification, same LDG placement ==
    let (perm, map) = partition_layout(&strategies[0].1, k).expect("layout");
    let pds = ds.relabel_with(&perm);
    let map: Arc<PartitionMap> = Arc::new(map);
    let dim = pds.num_features();
    let mut remote_bytes = std::collections::HashMap::new();
    for (label, kind) in [
        ("labor0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("ns", SamplerKind::Neighbor),
    ] {
        let s = MultiLayerSampler::new(kind, &[10, 10]);
        let ps = PartitionedStore::split(&pds.features, dim, map.clone(), tier);
        let mut pool = ScratchPool::new();
        pool.set_partition_map(Some(map.clone()));
        let mut out = Vec::new();
        for (i, seeds) in batches(nv as u32, nbatch, bsize, 0x5EED).iter().enumerate() {
            let mfg = s.sample_sharded(&pds.graph, seeds, i as u64, 4, &mut pool);
            let ids = mfg.feature_vertices();
            ps.gather_from(ps.home_for(ids), ids, &mut out);
        }
        let per_batch = ps.remote_bytes() as f64 / nbatch as f64;
        remote_bytes.insert(label, per_batch);
        println!("bytes: {label:<10} remote {:.1} KiB/batch", per_batch / 1024.0);
    }
    let amplification = remote_bytes["ns"] / remote_bytes["labor0"].max(1.0);
    assert!(
        amplification > 1.0,
        "NS must move more remote bytes than LABOR-0 (got {amplification:.2}x): \
         the frontier is the traffic"
    );
    println!("bytes: NS/LABOR-0 remote amplification {amplification:.2}x");

    let report = Json::obj(vec![
        ("bench", Json::Str("partition".into())),
        ("smoke", Json::Bool(smoke)),
        ("partitions", Json::Num(k as f64)),
        ("slack", Json::Num(slack)),
        ("cut_fraction_ldg", Json::Num(cut_fraction["ldg"])),
        ("cut_fraction_contiguous", Json::Num(cut_fraction["contiguous"])),
        ("cut_fraction_random", Json::Num(cut_fraction["random"])),
        ("local_hit_ldg", Json::Num(local_hit["ldg"])),
        ("local_hit_contiguous", Json::Num(local_hit["contiguous"])),
        ("local_hit_random", Json::Num(local_hit["random"])),
        ("priced_gather_us_ldg", Json::Num(priced_us["ldg"])),
        ("priced_gather_us_random", Json::Num(priced_us["random"])),
        ("priced_gather_us_unpartitioned", Json::Num(unpart_us)),
        ("remote_amplification_ns_over_labor0", Json::Num(amplification)),
    ]);
    std::fs::write("BENCH_partition.json", format!("{report}\n"))
        .expect("write BENCH_partition.json");
    println!("wrote BENCH_partition.json");
}
