//! PJRT execution latency: pack + train_step per dataset artifact — the L2
//! hot-path numbers behind the it/s columns (skips configs whose artifacts
//! are missing; run `make artifacts`).

use labor_gnn::data::Dataset;
use labor_gnn::runtime::{Engine, Manifest};
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind, SamplerScratch};
use labor_gnn::train::Trainer;
use labor_gnn::util::timer::bench;

fn main() {
    let Ok(man) = Manifest::load("artifacts") else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        return;
    };
    let engine = Engine::cpu().expect("pjrt cpu");
    for name in ["gcn_tiny", "gcn_flickr-sim"] {
        let Ok(model) = engine.load_model(&man, name) else {
            eprintln!("SKIP {name}: artifact missing");
            continue;
        };
        let dataset = name.trim_start_matches("gcn_");
        let scale = if dataset == "tiny" { 1.0 } else { 0.1 };
        let ds = Dataset::load_or_generate(dataset, scale).expect("dataset");
        let sampler = MultiLayerSampler::new(
            SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false },
            &[10, 10, 10],
        );
        let b = model.cfg.batch_size.min(ds.splits.train.len());
        let mut trainer = Trainer::new(model, 1).expect("trainer");
        let seeds: Vec<u32> = ds.splits.train[..b].to_vec();
        let mut scratch = SamplerScratch::new();
        let mfg = sampler.sample(&ds.graph, &seeds, 0, &mut scratch);

        // pack-only cost
        let r = bench(2, 10, || {
            std::hint::black_box(trainer.packer.pack(&ds, &mfg).unwrap());
        });
        r.report(&format!("pack/{name}"));

        // full step (pack + PJRT execute + state absorb)
        let mut s = 0u64;
        let r = bench(2, 10, || {
            let mfg = sampler.sample(&ds.graph, &seeds, s, &mut scratch);
            std::hint::black_box(trainer.step(&ds, &mfg).unwrap());
            s += 1;
        });
        r.report(&format!("train_step/{name}"));
    }
}
