//! Online serving QoS: coalesced-LABOR vs one-at-a-time NS.
//!
//! An open-loop Zipf request stream (popularity = degree rank, the
//! serving-realistic skew) is replayed through the coalescing front end
//! (`coordinator::serving`) at several arrival rates and window sizes,
//! against a solo baseline — the *same* front-end machinery with
//! `max_batch = 1` and a zero window, so the only variable is coalescing.
//! Reported per series: response-time p50/p99, the coalescing factor, and
//! feature bytes per request (gathered = what the shared pass fetched;
//! returned = what per-request serving hands back — their ratio is the
//! §3.2 shared-variate dedup win, measured at the serving boundary).
//!
//! Results go to `BENCH_serving.json` (asserted + printed by ci.sh). The
//! bench itself asserts the headline: at the highest arrival rate,
//! coalesced LABOR-0 gathers fewer bytes per request than one-at-a-time
//! NS.
//!
//! A second section measures serving **under chaos and overload**: the
//! same Zipf stream through bounded admission (`try_submit`) while a
//! failpoint schedule delays gathers and panics flushes, comparing a
//! fixed-fanout front end against one running the degradation ladder
//! (`DegradeConfig`) — the LABOR-native response to overload: step the
//! fanout budget down instead of shedding or missing deadlines. Results
//! go to `BENCH_chaos.json` (`degraded_p99_ms`, `shed_rate`).
//!
//! `cargo bench --bench serving` — full run.
//! `cargo bench --bench serving -- --smoke` — tiny request counts.

use labor_gnn::coordinator::cache::NullCache;
use labor_gnn::coordinator::feature_store::{FeatureStore, TierModel};
use labor_gnn::coordinator::pipeline::DataPlaneConfig;
use labor_gnn::coordinator::serving::{
    replay_open_loop, ServeError, ServingConfig, ServingFrontEnd,
};
use labor_gnn::coordinator::{Backoff, DegradeConfig, FailurePolicy, ServingSnapshot};
use labor_gnn::data::Dataset;
use labor_gnn::graph::compact::degree_order;
use labor_gnn::graph::gen::{zipf_requests, ZipfRequestConfig};
use labor_gnn::graph::CscGraph;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind};
use labor_gnn::util::failpoint;
use labor_gnn::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[allow(clippy::too_many_arguments)]
fn run_serving(
    graph: &Arc<CscGraph>,
    ds: &Dataset,
    kind: SamplerKind,
    fanouts: &[usize],
    seeds: &[u32],
    gaps: &[Duration],
    window: Duration,
    max_batch: usize,
    memo_rows: usize,
) -> ServingSnapshot {
    let store = FeatureStore::new(ds.features.clone(), ds.num_features(), TierModel::local())
        .with_cache(Arc::new(NullCache));
    let front = ServingFrontEnd::spawn(
        graph.clone(),
        Arc::new(MultiLayerSampler::new(kind, fanouts)),
        ServingConfig {
            window,
            max_batch,
            queue_depth: 4096,
            // generous deadline: this bench measures latency and bytes,
            // not admission-control behavior
            default_deadline: Duration::from_secs(10),
            seed: 7,
            intra_batch_threads: 1,
            sample_memo_rows: memo_rows,
            data_plane: Some(DataPlaneConfig {
                store: Arc::new(store),
                labels: None,
                partitioned: None,
            }),
            output_perm: None,
            failure_policy: FailurePolicy::Propagate,
            degrade: None,
        },
    );
    let handle = front.handle();
    let pending = replay_open_loop(&handle, seeds, gaps);
    drop(handle);
    for p in pending {
        p.wait().expect("request failed");
    }
    let snap = front.shutdown();
    assert_eq!(snap.served + snap.expired, seeds.len() as u64, "lost responses");
    snap
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// One chaos/overload series: bounded admission, a supervised worker, and
/// every terminal outcome tallied — the conservation law (served +
/// expired + failed + died + shed == submitted) is asserted, not assumed.
struct ChaosOutcome {
    snap: ServingSnapshot,
    submitted: u64,
    shed: u64,
    served: u64,
    expired: u64,
    failed: u64,
    died: u64,
}

impl ChaosOutcome {
    fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.submitted as f64
    }
}

fn run_chaos(
    graph: &Arc<CscGraph>,
    ds: &Dataset,
    seeds: &[u32],
    gaps: &[Duration],
    degrade: Option<DegradeConfig>,
    chaos_spec: Option<&str>,
) -> ChaosOutcome {
    failpoint::disarm_all();
    if let Some(spec) = chaos_spec {
        failpoint::arm_spec(spec, 7).expect("chaos spec");
    }
    let store = FeatureStore::new(ds.features.clone(), ds.num_features(), TierModel::local())
        .with_cache(Arc::new(NullCache));
    let front = ServingFrontEnd::spawn(
        graph.clone(),
        Arc::new(MultiLayerSampler::new(
            SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
            &[10, 10],
        )),
        ServingConfig {
            window: Duration::from_micros(500),
            max_batch: 16,
            queue_depth: 128,
            default_deadline: Duration::from_millis(20),
            seed: 7,
            intra_batch_threads: 1,
            sample_memo_rows: 0,
            data_plane: Some(DataPlaneConfig {
                store: Arc::new(store),
                labels: None,
                partitioned: None,
            }),
            output_perm: None,
            failure_policy: FailurePolicy::Supervise {
                max_restarts: 10_000,
                max_retries: 3,
                backoff: Backoff::default(),
            },
            degrade,
        },
    );
    let handle = front.handle();
    // open-loop replay through *bounded* admission: unlike
    // `replay_open_loop` (blocking submit), a full queue sheds here
    let start = Instant::now();
    let mut due = Duration::ZERO;
    let mut shed = 0u64;
    let mut pending = Vec::with_capacity(seeds.len());
    for (i, &s) in seeds.iter().enumerate() {
        due += gaps.get(i).copied().unwrap_or(Duration::ZERO);
        let elapsed = start.elapsed();
        if due > elapsed {
            std::thread::sleep(due - elapsed);
        }
        match handle.try_submit(s) {
            Ok(p) => pending.push(p),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    drop(handle);
    let (mut served, mut expired, mut failed, mut died) = (0u64, 0u64, 0u64, 0u64);
    for p in pending {
        match p.wait() {
            Ok(_) => served += 1,
            Err(ServeError::DeadlineExpired { .. }) => expired += 1,
            Err(ServeError::Failed { .. }) => failed += 1,
            Err(ServeError::WorkerDied { .. }) => died += 1,
            Err(e) => panic!("unexpected outcome: {e}"),
        }
    }
    let snap = front.shutdown();
    failpoint::disarm_all();
    let submitted = seeds.len() as u64;
    assert_eq!(
        served + expired + failed + died + shed,
        submitted,
        "a request fell through the outcome accounting"
    );
    assert_eq!(snap.faults.shed, shed, "shed accounting disagrees with admission");
    ChaosOutcome { snap, submitted, shed, served, expired, failed, died }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ds = Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset");
    let graph = Arc::new(ds.graph.clone());
    let order = degree_order(&graph);
    let fanouts = [10usize, 10];
    let requests: usize = if smoke { 150 } else { 1000 };
    let skew = 1.0f64;
    let rates = [500.0f64, 2000.0, 8000.0];
    let windows_us = [500u64, 2000];
    let max_batch = 64usize;

    println!(
        "== serving: coalesced labor-0 vs solo ns, flickr-sim 0.1, fanout 10x2, \
         {requests} requests/series, zipf skew {skew} over degree rank"
    );
    println!(
        "{:<18} {:>8} {:>10} {:>8} {:>9} {:>9} {:>9} {:>11} {:>11}",
        "mode", "req/s", "window", "coalesce", "p50 ms", "p99 ms", "mean ms", "B/req gath", "B/req ret"
    );

    let mut series = Vec::new();
    let mut record = |mode: &str, rate: f64, window_us: u64, snap: &ServingSnapshot| {
        println!(
            "{:<18} {:>8.0} {:>8}us {:>8.2} {:>9.3} {:>9.3} {:>9.3} {:>11.0} {:>11.0}",
            mode,
            rate,
            window_us,
            snap.coalescing_factor(),
            ms(snap.latency.p50),
            ms(snap.latency.p99),
            ms(snap.latency.mean),
            snap.bytes_gathered_per_request(),
            snap.bytes_returned_per_request(),
        );
        series.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("rate_hz", Json::Num(rate)),
            ("window_us", Json::Num(window_us as f64)),
            ("requests", Json::Num(requests as f64)),
            ("served", Json::Num(snap.served as f64)),
            ("expired", Json::Num(snap.expired as f64)),
            ("batches", Json::Num(snap.batches as f64)),
            ("coalescing_factor", Json::Num(snap.coalescing_factor())),
            ("p50_ms", Json::Num(ms(snap.latency.p50))),
            ("p90_ms", Json::Num(ms(snap.latency.p90))),
            ("p99_ms", Json::Num(ms(snap.latency.p99))),
            ("mean_ms", Json::Num(ms(snap.latency.mean))),
            ("max_ms", Json::Num(ms(snap.latency.max))),
            ("bytes_gathered_per_request", Json::Num(snap.bytes_gathered_per_request())),
            ("bytes_returned_per_request", Json::Num(snap.bytes_returned_per_request())),
            ("dedup_ratio", Json::Num(snap.dedup_ratio())),
            ("memo_hits", Json::Num(snap.memo_hits as f64)),
            ("memo_hit_rate", Json::Num(snap.memo_hit_rate())),
        ]));
    };

    // headline comparison, filled in during the sweep
    let mut coalesced_best: Option<f64> = None;
    let mut solo_at_max_rate: Option<f64> = None;
    let mut memo_hit_rate_at_max_rate: Option<f64> = None;

    for &rate in &rates {
        // the two serving modes share one request stream per rate: same
        // seeds, same arrival times — coalescing is the only variable
        let stream = zipf_requests(&ZipfRequestConfig {
            num_ids: graph.num_vertices(),
            exponent: skew,
            num_requests: requests,
            rate_hz: rate,
            seed: 42,
        });
        let seeds: Vec<u32> = stream.seeds.iter().map(|&r| order[r as usize]).collect();

        for &window_us in &windows_us {
            let snap = run_serving(
                &graph,
                &ds,
                SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
                &fanouts,
                &seeds,
                &stream.gaps,
                Duration::from_micros(window_us),
                max_batch,
                0,
            );
            if rate == rates[rates.len() - 1] && window_us == windows_us[windows_us.len() - 1]
            {
                coalesced_best = Some(snap.bytes_gathered_per_request());
            }
            record("coalesced-labor0", rate, window_us, &snap);
        }

        // memoized variant of the widest-window series: hot-vertex LABOR-0
        // blocks reused across flushes within one variate epoch
        // (`sample_memo_rows` spanning the whole graph; the Zipf skew is
        // what makes the hit rate interesting)
        let memo_window = windows_us[windows_us.len() - 1];
        let snap = run_serving(
            &graph,
            &ds,
            SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
            &fanouts,
            &seeds,
            &stream.gaps,
            Duration::from_micros(memo_window),
            max_batch,
            graph.num_vertices(),
        );
        if rate == rates[rates.len() - 1] {
            assert!(
                snap.memo_hit_rate() > 0.0,
                "a Zipf stream over a full-graph memo must reuse blocks"
            );
            memo_hit_rate_at_max_rate = Some(snap.memo_hit_rate());
        }
        record("coalesced-memo", rate, memo_window, &snap);

        let snap = run_serving(
            &graph,
            &ds,
            SamplerKind::Neighbor,
            &fanouts,
            &seeds,
            &stream.gaps,
            Duration::ZERO,
            1,
            0,
        );
        if rate == rates[rates.len() - 1] {
            solo_at_max_rate = Some(snap.bytes_gathered_per_request());
        }
        record("solo-ns", rate, 0, &snap);
    }

    // the serving-layer restatement of the paper's data-movement claim:
    // under load, coalesced LABOR-0 fetches fewer feature bytes per
    // request than sampling each request alone with NS
    let (coalesced, solo) = (coalesced_best.unwrap(), solo_at_max_rate.unwrap());
    assert!(
        coalesced < solo,
        "coalesced LABOR-0 gathered {coalesced:.0} B/req, expected < solo NS {solo:.0} B/req"
    );
    println!(
        "(coalesced LABOR-0 fetches {:.1}% of solo NS bytes/request at {} req/s)",
        coalesced / solo * 100.0,
        rates[rates.len() - 1]
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("serving".into())),
        ("dataset", Json::Str("flickr-sim".into())),
        ("scale", Json::Num(0.1)),
        ("smoke", Json::Bool(smoke)),
        ("fanouts", Json::Arr(fanouts.iter().map(|&f| Json::Num(f as f64)).collect())),
        ("requests_per_series", Json::Num(requests as f64)),
        ("zipf_exponent", Json::Num(skew)),
        ("max_batch", Json::Num(max_batch as f64)),
        // memoized-serving headline: fraction of per-seed LABOR-0 blocks
        // reused across flushes at the highest arrival rate
        ("serving_memo_hit_rate", Json::Num(memo_hit_rate_at_max_rate.unwrap_or(0.0))),
        ("series", Json::Arr(series)),
    ]);
    std::fs::write("BENCH_serving.json", format!("{report}\n"))
        .expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json");

    // == chaos & graceful degradation ==
    //
    // Same machinery, hostile conditions: an overload-rate stream through
    // bounded admission while a failpoint schedule delays every 3rd
    // gather and panics every 25th flush. The comparison is fixed fanout
    // vs the degradation ladder, which trades sampled-neighborhood size
    // (the paper's budget knob) for deadline headroom under pressure.
    let chaos_requests: usize = if smoke { 200 } else { 1200 };
    let chaos_rate = 12_000.0f64;
    const CHAOS_SPEC: &str = "gather=delay:400us@every3;sample_flush=panic@every25";
    let stream = zipf_requests(&ZipfRequestConfig {
        num_ids: graph.num_vertices(),
        exponent: skew,
        num_requests: chaos_requests,
        rate_hz: chaos_rate,
        seed: 43,
    });
    let seeds: Vec<u32> = stream.seeds.iter().map(|&r| order[r as usize]).collect();
    let ladder_cfg = DegradeConfig {
        ladder: vec![10, 7, 4],
        down_after: 2,
        up_after: 8,
        // floor above the deadline: every flush of this overload series
        // counts as pressured, so the ladder engages deterministically
        headroom: Duration::from_millis(50),
        queue_high: 96,
    };

    println!(
        "\n== serving under chaos: {chaos_requests} requests at {chaos_rate:.0} req/s, \
         spec '{CHAOS_SPEC}', supervised worker, queue depth 128"
    );
    println!(
        "{:<14} {:>7} {:>6} {:>7} {:>6} {:>5} {:>8} {:>8} {:>8} {:>9} {:>9}",
        "mode", "served", "shed", "expired", "failed", "died", "restarts", "retried", "degraded",
        "p50 ms", "p99 ms"
    );
    let mut chaos_series = Vec::new();
    let mut chaos_record = |mode: &str, out: &ChaosOutcome| {
        println!(
            "{:<14} {:>7} {:>6} {:>7} {:>6} {:>5} {:>8} {:>8} {:>8} {:>9.3} {:>9.3}",
            mode,
            out.served,
            out.shed,
            out.expired,
            out.failed,
            out.died,
            out.snap.faults.restarts,
            out.snap.faults.retried,
            out.snap.faults.degraded,
            ms(out.snap.latency.p50),
            ms(out.snap.latency.p99),
        );
        chaos_series.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("submitted", Json::Num(out.submitted as f64)),
            ("served", Json::Num(out.served as f64)),
            ("shed", Json::Num(out.shed as f64)),
            ("expired", Json::Num(out.expired as f64)),
            ("failed", Json::Num(out.failed as f64)),
            ("died", Json::Num(out.died as f64)),
            ("restarts", Json::Num(out.snap.faults.restarts as f64)),
            ("retried", Json::Num(out.snap.faults.retried as f64)),
            ("degraded", Json::Num(out.snap.faults.degraded as f64)),
            ("shed_rate", Json::Num(out.shed_rate())),
            ("p50_ms", Json::Num(ms(out.snap.latency.p50))),
            ("p99_ms", Json::Num(ms(out.snap.latency.p99))),
            ("mean_ms", Json::Num(ms(out.snap.latency.mean))),
        ]));
    };

    let clean = run_chaos(&graph, &ds, &seeds, &stream.gaps, None, None);
    chaos_record("clean", &clean);
    let fixed = run_chaos(&graph, &ds, &seeds, &stream.gaps, None, Some(CHAOS_SPEC));
    chaos_record("chaos-fixed", &fixed);
    let ladder =
        run_chaos(&graph, &ds, &seeds, &stream.gaps, Some(ladder_cfg), Some(CHAOS_SPEC));
    chaos_record("chaos-ladder", &ladder);

    // the mechanism must engage: pressured-by-construction flushes walk
    // the ladder down within two flushes, so served responses carry caps
    assert!(
        ladder.snap.faults.degraded > 0,
        "the degradation ladder never engaged under overload"
    );
    assert_eq!(clean.snap.faults.restarts, 0, "clean series must not restart");
    println!(
        "(ladder p99 {:.3} ms vs fixed {:.3} ms under chaos; {:.1}% of ladder responses \
         served degraded, shed rate {:.3})",
        ms(ladder.snap.latency.p99),
        ms(fixed.snap.latency.p99),
        ladder.snap.faults.degraded as f64 / ladder.served.max(1) as f64 * 100.0,
        ladder.shed_rate(),
    );

    let chaos_report = Json::obj(vec![
        ("bench", Json::Str("chaos".into())),
        ("dataset", Json::Str("flickr-sim".into())),
        ("scale", Json::Num(0.1)),
        ("smoke", Json::Bool(smoke)),
        ("requests", Json::Num(chaos_requests as f64)),
        ("rate_hz", Json::Num(chaos_rate)),
        ("chaos_spec", Json::Str(CHAOS_SPEC.into())),
        ("ladder", Json::Arr(vec![Json::Num(10.0), Json::Num(7.0), Json::Num(4.0)])),
        // the two headline numbers: tail latency while degrading, and the
        // fraction of load shed at admission, both from the ladder series
        ("degraded_p99_ms", Json::Num(ms(ladder.snap.latency.p99))),
        ("shed_rate", Json::Num(ladder.shed_rate())),
        ("series", Json::Arr(chaos_series)),
    ]);
    std::fs::write("BENCH_chaos.json", format!("{chaos_report}\n"))
        .expect("write BENCH_chaos.json");
    println!("wrote BENCH_chaos.json");
}
