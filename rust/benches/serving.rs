//! Online serving QoS: coalesced-LABOR vs one-at-a-time NS.
//!
//! An open-loop Zipf request stream (popularity = degree rank, the
//! serving-realistic skew) is replayed through the coalescing front end
//! (`coordinator::serving`) at several arrival rates and window sizes,
//! against a solo baseline — the *same* front-end machinery with
//! `max_batch = 1` and a zero window, so the only variable is coalescing.
//! Reported per series: response-time p50/p99, the coalescing factor, and
//! feature bytes per request (gathered = what the shared pass fetched;
//! returned = what per-request serving hands back — their ratio is the
//! §3.2 shared-variate dedup win, measured at the serving boundary).
//!
//! Results go to `BENCH_serving.json` (asserted + printed by ci.sh). The
//! bench itself asserts the headline: at the highest arrival rate,
//! coalesced LABOR-0 gathers fewer bytes per request than one-at-a-time
//! NS.
//!
//! `cargo bench --bench serving` — full run.
//! `cargo bench --bench serving -- --smoke` — tiny request counts.

use labor_gnn::coordinator::cache::NullCache;
use labor_gnn::coordinator::feature_store::{FeatureStore, TierModel};
use labor_gnn::coordinator::pipeline::DataPlaneConfig;
use labor_gnn::coordinator::serving::{replay_open_loop, ServingConfig, ServingFrontEnd};
use labor_gnn::coordinator::ServingSnapshot;
use labor_gnn::data::Dataset;
use labor_gnn::graph::compact::degree_order;
use labor_gnn::graph::gen::{zipf_requests, ZipfRequestConfig};
use labor_gnn::graph::CscGraph;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind};
use labor_gnn::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

#[allow(clippy::too_many_arguments)]
fn run_serving(
    graph: &Arc<CscGraph>,
    ds: &Dataset,
    kind: SamplerKind,
    fanouts: &[usize],
    seeds: &[u32],
    gaps: &[Duration],
    window: Duration,
    max_batch: usize,
) -> ServingSnapshot {
    let store = FeatureStore::new(ds.features.clone(), ds.num_features(), TierModel::local())
        .with_cache(Arc::new(NullCache));
    let front = ServingFrontEnd::spawn(
        graph.clone(),
        Arc::new(MultiLayerSampler::new(kind, fanouts)),
        ServingConfig {
            window,
            max_batch,
            queue_depth: 4096,
            // generous deadline: this bench measures latency and bytes,
            // not admission-control behavior
            default_deadline: Duration::from_secs(10),
            seed: 7,
            intra_batch_threads: 1,
            data_plane: Some(DataPlaneConfig { store: Arc::new(store), labels: None }),
            output_perm: None,
        },
    );
    let handle = front.handle();
    let pending = replay_open_loop(&handle, seeds, gaps);
    drop(handle);
    for p in pending {
        p.wait().expect("request failed");
    }
    let snap = front.shutdown();
    assert_eq!(snap.served + snap.expired, seeds.len() as u64, "lost responses");
    snap
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ds = Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset");
    let graph = Arc::new(ds.graph.clone());
    let order = degree_order(&graph);
    let fanouts = [10usize, 10];
    let requests: usize = if smoke { 150 } else { 1000 };
    let skew = 1.0f64;
    let rates = [500.0f64, 2000.0, 8000.0];
    let windows_us = [500u64, 2000];
    let max_batch = 64usize;

    println!(
        "== serving: coalesced labor-0 vs solo ns, flickr-sim 0.1, fanout 10x2, \
         {requests} requests/series, zipf skew {skew} over degree rank"
    );
    println!(
        "{:<18} {:>8} {:>10} {:>8} {:>9} {:>9} {:>9} {:>11} {:>11}",
        "mode", "req/s", "window", "coalesce", "p50 ms", "p99 ms", "mean ms", "B/req gath", "B/req ret"
    );

    let mut series = Vec::new();
    let mut record = |mode: &str, rate: f64, window_us: u64, snap: &ServingSnapshot| {
        println!(
            "{:<18} {:>8.0} {:>8}us {:>8.2} {:>9.3} {:>9.3} {:>9.3} {:>11.0} {:>11.0}",
            mode,
            rate,
            window_us,
            snap.coalescing_factor(),
            ms(snap.latency.p50),
            ms(snap.latency.p99),
            ms(snap.latency.mean),
            snap.bytes_gathered_per_request(),
            snap.bytes_returned_per_request(),
        );
        series.push(Json::obj(vec![
            ("mode", Json::Str(mode.into())),
            ("rate_hz", Json::Num(rate)),
            ("window_us", Json::Num(window_us as f64)),
            ("requests", Json::Num(requests as f64)),
            ("served", Json::Num(snap.served as f64)),
            ("expired", Json::Num(snap.expired as f64)),
            ("batches", Json::Num(snap.batches as f64)),
            ("coalescing_factor", Json::Num(snap.coalescing_factor())),
            ("p50_ms", Json::Num(ms(snap.latency.p50))),
            ("p90_ms", Json::Num(ms(snap.latency.p90))),
            ("p99_ms", Json::Num(ms(snap.latency.p99))),
            ("mean_ms", Json::Num(ms(snap.latency.mean))),
            ("max_ms", Json::Num(ms(snap.latency.max))),
            ("bytes_gathered_per_request", Json::Num(snap.bytes_gathered_per_request())),
            ("bytes_returned_per_request", Json::Num(snap.bytes_returned_per_request())),
            ("dedup_ratio", Json::Num(snap.dedup_ratio())),
        ]));
    };

    // headline comparison, filled in during the sweep
    let mut coalesced_best: Option<f64> = None;
    let mut solo_at_max_rate: Option<f64> = None;

    for &rate in &rates {
        // the two serving modes share one request stream per rate: same
        // seeds, same arrival times — coalescing is the only variable
        let stream = zipf_requests(&ZipfRequestConfig {
            num_ids: graph.num_vertices(),
            exponent: skew,
            num_requests: requests,
            rate_hz: rate,
            seed: 42,
        });
        let seeds: Vec<u32> = stream.seeds.iter().map(|&r| order[r as usize]).collect();

        for &window_us in &windows_us {
            let snap = run_serving(
                &graph,
                &ds,
                SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false },
                &fanouts,
                &seeds,
                &stream.gaps,
                Duration::from_micros(window_us),
                max_batch,
            );
            if rate == rates[rates.len() - 1] && window_us == windows_us[windows_us.len() - 1]
            {
                coalesced_best = Some(snap.bytes_gathered_per_request());
            }
            record("coalesced-labor0", rate, window_us, &snap);
        }

        let snap = run_serving(
            &graph,
            &ds,
            SamplerKind::Neighbor,
            &fanouts,
            &seeds,
            &stream.gaps,
            Duration::ZERO,
            1,
        );
        if rate == rates[rates.len() - 1] {
            solo_at_max_rate = Some(snap.bytes_gathered_per_request());
        }
        record("solo-ns", rate, 0, &snap);
    }

    // the serving-layer restatement of the paper's data-movement claim:
    // under load, coalesced LABOR-0 fetches fewer feature bytes per
    // request than sampling each request alone with NS
    let (coalesced, solo) = (coalesced_best.unwrap(), solo_at_max_rate.unwrap());
    assert!(
        coalesced < solo,
        "coalesced LABOR-0 gathered {coalesced:.0} B/req, expected < solo NS {solo:.0} B/req"
    );
    println!(
        "(coalesced LABOR-0 fetches {:.1}% of solo NS bytes/request at {} req/s)",
        coalesced / solo * 100.0,
        rates[rates.len() - 1]
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("serving".into())),
        ("dataset", Json::Str("flickr-sim".into())),
        ("scale", Json::Num(0.1)),
        ("smoke", Json::Bool(smoke)),
        ("fanouts", Json::Arr(fanouts.iter().map(|&f| Json::Num(f as f64)).collect())),
        ("requests_per_series", Json::Num(requests as f64)),
        ("zipf_exponent", Json::Num(skew)),
        ("max_batch", Json::Num(max_batch as f64)),
        ("series", Json::Arr(series)),
    ]);
    std::fs::write("BENCH_serving.json", format!("{report}\n"))
        .expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json");
}
