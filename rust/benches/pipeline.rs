//! Coordinator pipeline throughput and allocation behavior.
//!
//! Three sections:
//! 1. batches/s as a function of worker count (the L3 §Perf scaling
//!    check) — each worker holds a long-lived `SamplerScratch`;
//! 2. single-thread steady-state batches/s, warm scratch vs a fresh
//!    scratch per call (the arena win in isolation);
//! 3. an allocation probe: a counting global allocator reports
//!    allocations and bytes per batch for warm vs fresh scratch, making
//!    "no per-batch O(|V|) allocation" measurable.
//!
//! `cargo bench --bench pipeline` — full run.
//! `cargo bench --bench pipeline -- --smoke` — tiny iteration counts
//! (CI gate: proves the bench targets build and run; see ci.sh).

use labor_gnn::coordinator::pipeline::{PipelineConfig, SamplingPipeline};
use labor_gnn::data::Dataset;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind, SamplerScratch};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Counting wrapper around the system allocator: cumulative *allocated*
/// bytes (frees are not subtracted; `realloc` counts only its growth
/// delta, so a Vec grown through doubling is not double-counted).
/// Counters are global, so the probe section runs single-threaded with no
/// pipeline active. Note the two relaxed atomic RMWs per allocation are
/// paid by every section of this binary — a uniform, tiny tax on the
/// throughput numbers.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size.saturating_sub(layout.size()) as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn counters() -> (u64, u64) {
    (ALLOCS.load(Ordering::Relaxed), BYTES.load(Ordering::Relaxed))
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ds = Arc::new(Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset"));
    let graph = Arc::new(ds.graph.clone());
    let ids = Arc::new(ds.splits.train.clone());
    let batches: u64 = if smoke { 6 } else { 60 };

    println!("== pipeline throughput, labor-1, batch 1024, {batches} batches");
    for workers in [1usize, 2, 4, 8] {
        let sampler = Arc::new(MultiLayerSampler::new(
            SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false },
            &[10, 10, 10],
        ));
        let t0 = Instant::now();
        let mut p = SamplingPipeline::spawn(
            graph.clone(),
            sampler,
            ids.clone(),
            PipelineConfig {
                num_workers: workers,
                queue_depth: 8,
                batch_size: 1024,
                num_batches: batches,
                seed: 3,
            },
        );
        let mut n = 0;
        for b in &mut p {
            std::hint::black_box(b.mfg.vertex_counts());
            n += 1;
        }
        p.join();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "workers={workers}: {n} batches in {dt:.2}s = {:.1} batches/s",
            n as f64 / dt
        );
    }

    // -- warm scratch vs fresh scratch, single thread -----------------
    let sampler = MultiLayerSampler::new(
        SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false },
        &[10, 10, 10],
    );
    let seeds: Vec<u32> = ids[..1024.min(ids.len())].to_vec();
    let reps: u64 = if smoke { 4 } else { 40 };

    println!("\n== steady-state sampling, single thread, labor-1, {reps} batches");
    let mut scratch = SamplerScratch::for_vertices(graph.num_vertices());
    // warm up: size the arena to steady state before timing
    for b in 0..3u64 {
        std::hint::black_box(sampler.sample(&graph, &seeds, b, &mut scratch));
    }
    let t0 = Instant::now();
    for b in 0..reps {
        std::hint::black_box(sampler.sample(&graph, &seeds, b, &mut scratch));
    }
    let warm = t0.elapsed().as_secs_f64();
    println!("warm scratch : {:.1} batches/s", reps as f64 / warm);
    let t0 = Instant::now();
    for b in 0..reps {
        std::hint::black_box(sampler.sample_fresh(&graph, &seeds, b));
    }
    let fresh = t0.elapsed().as_secs_f64();
    println!("fresh scratch: {:.1} batches/s ({:.2}x)", reps as f64 / fresh, fresh / warm);

    // -- allocation probe ---------------------------------------------
    let probe = |label: &str, f: &mut dyn FnMut(u64)| {
        let n: u64 = if smoke { 3 } else { 10 };
        let (a0, b0) = counters();
        for b in 0..n {
            f(b);
        }
        let (a1, b1) = counters();
        println!(
            "{label}: {:.0} allocations / {:.1} KiB allocated per batch",
            (a1 - a0) as f64 / n as f64,
            (b1 - b0) as f64 / n as f64 / 1024.0
        );
    };
    println!(
        "\n== allocation probe, labor-1 3-layer, batch 1024, |V|={}",
        graph.num_vertices()
    );
    probe("warm scratch ", &mut |b| {
        std::hint::black_box(sampler.sample(&graph, &seeds, b, &mut scratch));
    });
    probe("fresh scratch", &mut |b| {
        std::hint::black_box(sampler.sample_fresh(&graph, &seeds, b));
    });
    println!(
        "(warm-scratch allocations are the MFG output vectors only — the \
         O(|V|) maps and every work buffer live in the arena)"
    );
}
