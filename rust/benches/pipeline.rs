//! Coordinator pipeline throughput and allocation behavior.
//!
//! Five sections:
//! 1. batches/s as a function of worker count (batch-parallel scaling) —
//!    each worker holds a long-lived `SamplerScratch`;
//! 2. batches/s as a function of `intra_batch_threads` with a single
//!    worker and one huge batch (shard-parallel scaling — the paper's
//!    large-batch regime, where batch-parallelism stops helping because
//!    one batch dominates the epoch);
//! 3. a data-plane gather sweep: NS vs LABOR-0 vs LABOR-\* with the
//!    in-pipeline feature gather under local/pcie/nvme tiers, degree
//!    cache on/off — feature bytes moved per epoch and effective
//!    batches/s (the paper's §4.1 feature-access-speed axis, measured);
//! 4. single-thread steady-state batches/s, warm scratch vs a fresh
//!    scratch per call (the arena win in isolation);
//! 5. an allocation probe: a counting global allocator reports
//!    allocations and bytes per batch for warm vs fresh scratch, making
//!    "no per-batch O(|V|) allocation" measurable.
//!
//! Sections 1 and 2 are written to `BENCH_pipeline.json` and section 3 to
//! `BENCH_datapipe.json` (machine-readable) so CI can track the perf
//! trajectory across PRs — see ci.sh and docs/BENCHMARKS.md.
//!
//! `cargo bench --bench pipeline` — full run.
//! `cargo bench --bench pipeline -- --smoke` — tiny iteration counts
//! (CI gate: proves the bench targets build and run; see ci.sh).

use labor_gnn::coordinator::cache::{DegreeOrderedCache, FeatureCache, NullCache};
use labor_gnn::coordinator::feature_store::{FeatureStore, TierModel};
use labor_gnn::coordinator::pipeline::{DataPlaneConfig, PipelineConfig, SamplingPipeline};
use labor_gnn::data::Dataset;
use labor_gnn::graph::CscGraph;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind, SamplerScratch};
use labor_gnn::util::json::Json;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Counting wrapper around the system allocator: cumulative *allocated*
/// bytes (frees are not subtracted; `realloc` counts only its growth
/// delta, so a Vec grown through doubling is not double-counted).
/// Counters are global, so the probe section runs single-threaded with no
/// pipeline active. Note the two relaxed atomic RMWs per allocation are
/// paid by every section of this binary — a uniform, tiny tax on the
/// throughput numbers.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size.saturating_sub(layout.size()) as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn counters() -> (u64, u64) {
    (ALLOCS.load(Ordering::Relaxed), BYTES.load(Ordering::Relaxed))
}

/// Run one pipeline to completion, return batches/s.
fn run_pipeline(graph: &Arc<CscGraph>, ids: &Arc<Vec<u32>>, cfg: PipelineConfig) -> f64 {
    let sampler = Arc::new(MultiLayerSampler::new(
        SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false },
        &[10, 10, 10],
    ));
    let n_cfg = cfg.num_batches;
    let t0 = Instant::now();
    let mut p = SamplingPipeline::spawn(graph.clone(), sampler, ids.clone(), cfg);
    let mut n = 0u64;
    for b in &mut p {
        std::hint::black_box(b.mfg.vertex_counts());
        n += 1;
    }
    p.join();
    assert_eq!(n, n_cfg);
    n as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ds = Arc::new(Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset"));
    let graph = Arc::new(ds.graph.clone());
    let ids = Arc::new(ds.splits.train.clone());
    let batches: u64 = if smoke { 6 } else { 60 };

    println!("== pipeline throughput (batch-parallel), labor-1, batch 1024, {batches} batches");
    let mut batch_parallel = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let rate = run_pipeline(
            &graph,
            &ids,
            PipelineConfig {
                num_workers: workers,
                queue_depth: 8,
                batch_size: 1024,
                num_batches: batches,
                seed: 3,
                intra_batch_threads: 1,
                data_plane: None,
                output_perm: None,
                ..PipelineConfig::default()
            },
        );
        println!("workers={workers}: {rate:.1} batches/s");
        batch_parallel.push(Json::obj(vec![
            ("workers", Json::Num(workers as f64)),
            ("batches_per_s", Json::Num(rate)),
        ]));
    }

    // -- shard-parallel scaling: the large-batch regime ----------------
    // one worker, one huge batch at a time: all speedup must come from
    // intra-batch seed sharding; threads=1 is the sequential baseline
    let big_batch = 4096.min(ids.len());
    let big_batches: u64 = if smoke { 3 } else { 20 };
    println!(
        "\n== pipeline throughput (shard-parallel), labor-1, batch {big_batch}, \
         {big_batches} batches, 1 worker"
    );
    let mut shard_parallel = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let rate = run_pipeline(
            &graph,
            &ids,
            PipelineConfig {
                num_workers: 1,
                queue_depth: 4,
                batch_size: big_batch,
                num_batches: big_batches,
                seed: 3,
                intra_batch_threads: threads,
                data_plane: None,
                output_perm: None,
                ..PipelineConfig::default()
            },
        );
        println!("intra_batch_threads={threads}: {rate:.2} batches/s");
        shard_parallel.push(Json::obj(vec![
            ("threads", Json::Num(threads as f64)),
            ("batches_per_s", Json::Num(rate)),
        ]));
    }

    // -- data-plane gather sweep: the §4.1 feature-speed axis ----------
    // Workers gather the deepest layer's feature rows in-pipeline through
    // a shared FeatureStore. Bytes moved per epoch depend on the sampler
    // (LABOR's fewer unique vertices => fewer rows) and the cache (top-10%
    // in-degree rows resident => misses only); the tier prices the misses.
    // Effective batches/s charges the simulated fetch time serially — the
    // pessimistic single-DMA-engine reading also used by the
    // streaming_pipeline example.
    // batch 256 keeps the 3-hop frontier well below the 0.1-scale graph's
    // vertex count — saturation would equalize NS and LABOR byte counts
    // and hide exactly the effect this section measures
    let dp_batch = 256usize;
    let dp_batches: u64 = if smoke { 4 } else { 30 };
    let feats_shared: Arc<Vec<f32>> = ds.features.clone();
    let dim = ds.spec.num_features;
    let cache_rows = graph.num_vertices() / 10;
    println!(
        "\n== data plane: in-pipeline gather, batch {dp_batch}, {dp_batches} batches, \
         4 workers, cache = top-{cache_rows} in-degree rows"
    );
    println!(
        "{:<8} {:>6} {:>6} {:>12} {:>12} {:>7} {:>12}",
        "sampler", "tier", "cache", "MB moved", "MB gathered", "hit%", "eff bat/s"
    );
    let mut datapipe = Vec::new();
    let mut local_uncached_bytes: Vec<(String, u64)> = Vec::new();
    // one shared policy instance: residency depends only on (graph, k)
    let deg_cache = Arc::new(DegreeOrderedCache::new(&graph, cache_rows));
    for (name, kind) in [
        ("ns", SamplerKind::Neighbor),
        ("labor-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("labor-*", SamplerKind::Labor { iterations: IterSpec::Converge, layer_dependent: false }),
    ] {
        for cached in [false, true] {
            // Measure once per (sampler, cache): gathered bytes are
            // tier-independent (determinism contract), so the three tier
            // rows are priced analytically from the recorded miss traffic
            // (FeatureStore::priced_time) instead of re-running the same
            // pipeline three times.
            let cache: Arc<dyn FeatureCache> =
                if cached { deg_cache.clone() } else { Arc::new(NullCache) };
            let store = Arc::new(
                FeatureStore::new(feats_shared.clone(), dim, TierModel::local())
                    .with_cache(cache),
            );
            let sampler = Arc::new(MultiLayerSampler::new(kind.clone(), &[10, 10, 10]));
            let t0 = Instant::now();
            let mut p = SamplingPipeline::spawn(
                graph.clone(),
                sampler,
                ids.clone(),
                PipelineConfig {
                    num_workers: 4,
                    queue_depth: 8,
                    batch_size: dp_batch,
                    num_batches: dp_batches,
                    seed: 3,
                    intra_batch_threads: 1,
                    data_plane: Some(DataPlaneConfig {
                        store: store.clone(),
                        labels: None,
                        partitioned: None,
                    }),
                    output_perm: None,
                    ..PipelineConfig::default()
                },
            );
            for b in &mut p {
                std::hint::black_box(b.feats.len());
            }
            p.join();
            let wall = t0.elapsed().as_secs_f64();
            let moved = store.bytes_fetched();
            if !cached {
                local_uncached_bytes.push((name.to_string(), moved));
            }
            for (tier_name, tier) in [
                ("local", TierModel::local()),
                ("pcie", TierModel::pcie()),
                ("nvme", TierModel::nvme()),
            ] {
                let rate =
                    dp_batches as f64 / (wall + store.priced_time(tier).as_secs_f64());
                println!(
                    "{:<8} {:>6} {:>6} {:>12.1} {:>12.1} {:>7.1} {:>12.2}",
                    name,
                    tier_name,
                    if cached { "deg" } else { "off" },
                    moved as f64 / 1e6,
                    store.bytes_gathered() as f64 / 1e6,
                    store.hit_rate() * 100.0,
                    rate
                );
                datapipe.push(Json::obj(vec![
                    ("sampler", Json::Str(name.into())),
                    ("tier", Json::Str(tier_name.into())),
                    ("cache_rows", Json::Num(if cached { cache_rows as f64 } else { 0.0 })),
                    ("bytes_moved", Json::Num(moved as f64)),
                    ("bytes_gathered", Json::Num(store.bytes_gathered() as f64)),
                    ("bytes_saved", Json::Num(store.bytes_saved() as f64)),
                    ("hit_rate", Json::Num(store.hit_rate())),
                    ("batches_per_s_effective", Json::Num(rate)),
                ]));
            }
        }
    }
    // the paper's headline data-movement claim must hold on this graph:
    // LABOR-0 moves measurably fewer feature bytes per epoch than NS
    let bytes_of = |label: &str| -> u64 {
        local_uncached_bytes.iter().find(|(n, _)| n == label).expect("series present").1
    };
    let (ns_b, l0_b) = (bytes_of("ns"), bytes_of("labor-0"));
    assert!(
        l0_b < ns_b,
        "LABOR-0 moved {l0_b} bytes, expected fewer than NS's {ns_b}"
    );
    println!(
        "(LABOR-0 moves {:.1}% of NS's feature bytes at equal fanout)",
        l0_b as f64 / ns_b as f64 * 100.0
    );
    // -- SIMD vs scalar feature-row gather (micro) ---------------------
    // The same rows through both FeatureStore::gather code paths: the
    // wide-copy + prefetch path and the scalar reference, asserted
    // bit-identical before timing.
    use labor_gnn::util::simd;
    let rows = (feats_shared.len() / dim) as u64;
    let mut grng = labor_gnn::rng::StreamRng::new(7);
    let gather_n: usize = if smoke { 4_096 } else { 262_144 };
    let gather_iters: usize = if smoke { 3 } else { 20 };
    let gids: Vec<u32> = (0..gather_n).map(|_| grng.below(rows) as u32).collect();
    let mut out_simd = Vec::new();
    let mut out_scalar = Vec::new();
    simd::gather_rows_f32_simd(feats_shared.as_slice(), dim, &gids, &mut out_simd);
    simd::gather_rows_f32_scalar(feats_shared.as_slice(), dim, &gids, &mut out_scalar);
    let identical = out_simd.len() == out_scalar.len()
        && out_simd.iter().zip(&out_scalar).all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(identical, "SIMD gather must be bit-identical to scalar");
    let t0 = Instant::now();
    for _ in 0..gather_iters {
        out_simd.clear();
        simd::gather_rows_f32_simd(feats_shared.as_slice(), dim, &gids, &mut out_simd);
        std::hint::black_box(out_simd.len());
    }
    let simd_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..gather_iters {
        out_scalar.clear();
        simd::gather_rows_f32_scalar(feats_shared.as_slice(), dim, &gids, &mut out_scalar);
        std::hint::black_box(out_scalar.len());
    }
    let scalar_s = t0.elapsed().as_secs_f64();
    println!(
        "\nsimd gather {gather_n} rows (dim {dim}) x{gather_iters}: simd {:.3} ms, \
         scalar {:.3} ms ({:.2}x, bit-identical)",
        simd_s * 1e3,
        scalar_s * 1e3,
        scalar_s / simd_s.max(1e-12)
    );

    let datapipe_report = Json::obj(vec![
        ("bench", Json::Str("datapipe".into())),
        ("dataset", Json::Str("flickr-sim".into())),
        ("scale", Json::Num(0.1)),
        ("smoke", Json::Bool(smoke)),
        ("fanouts", Json::Arr(vec![Json::Num(10.0); 3])),
        ("batch_size", Json::Num(dp_batch as f64)),
        ("num_batches", Json::Num(dp_batches as f64)),
        ("num_workers", Json::Num(4.0)),
        ("cache_rows", Json::Num(cache_rows as f64)),
        ("feature_dim", Json::Num(dim as f64)),
        (
            "simd_gather",
            Json::obj(vec![
                ("rows", Json::Num(gather_n as f64)),
                ("dim", Json::Num(dim as f64)),
                ("iters", Json::Num(gather_iters as f64)),
                ("simd_s", Json::Num(simd_s)),
                ("scalar_s", Json::Num(scalar_s)),
                ("identical", Json::Bool(identical)),
            ]),
        ),
        ("series", Json::Arr(datapipe)),
    ]);
    std::fs::write("BENCH_datapipe.json", format!("{datapipe_report}\n"))
        .expect("write BENCH_datapipe.json");
    println!("wrote BENCH_datapipe.json");

    // -- relabeled layout: end-to-end pipeline throughput --------------
    // The same epoch on the degree-ordered layout (graph, features, and
    // splits all permuted together; delivered batches are mapped back to
    // original ids by the workers via `output_perm`). Locality is the
    // only variable: same sampler, same logical seed sequence.
    println!("\n== relabeled-layout pipeline, labor-1, batch 1024, {batches} batches, 4 workers");
    let (rds, perm) = ds.relabel_by_degree();
    let perm = Arc::new(perm);
    let rgraph = Arc::new(rds.graph.clone());
    let rids = Arc::new(rds.splits.train.clone());
    let mut relabel_series = Vec::new();
    for (layout, g, id_list, output_perm) in [
        ("original", &graph, &ids, None),
        ("relabeled", &rgraph, &rids, Some(perm.clone())),
    ] {
        let rate = run_pipeline(
            g,
            id_list,
            PipelineConfig {
                num_workers: 4,
                queue_depth: 8,
                batch_size: 1024,
                num_batches: batches,
                seed: 3,
                intra_batch_threads: 1,
                data_plane: None,
                output_perm,
                ..PipelineConfig::default()
            },
        );
        println!("{layout}: {rate:.1} batches/s");
        relabel_series.push(Json::obj(vec![
            ("layout", Json::Str(layout.into())),
            ("batches_per_s", Json::Num(rate)),
        ]));
    }

    // machine-readable trajectory for CI (ci.sh asserts this file exists)
    let report = Json::obj(vec![
        ("bench", Json::Str("pipeline".into())),
        ("dataset", Json::Str("flickr-sim".into())),
        ("scale", Json::Num(0.1)),
        ("smoke", Json::Bool(smoke)),
        ("sampler", Json::Str("labor-1".into())),
        (
            "batch_parallel",
            Json::obj(vec![
                ("batch_size", Json::Num(1024.0)),
                ("num_batches", Json::Num(batches as f64)),
                ("series", Json::Arr(batch_parallel)),
            ]),
        ),
        (
            "shard_parallel",
            Json::obj(vec![
                ("batch_size", Json::Num(big_batch as f64)),
                ("num_batches", Json::Num(big_batches as f64)),
                ("series", Json::Arr(shard_parallel)),
            ]),
        ),
        (
            "relabeled_pipeline",
            Json::obj(vec![
                ("batch_size", Json::Num(1024.0)),
                ("num_batches", Json::Num(batches as f64)),
                ("series", Json::Arr(relabel_series)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_pipeline.json", format!("{report}\n")).expect("write BENCH_pipeline.json");
    println!("\nwrote BENCH_pipeline.json");

    // -- warm scratch vs fresh scratch, single thread -----------------
    let sampler = MultiLayerSampler::new(
        SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false },
        &[10, 10, 10],
    );
    let seeds: Vec<u32> = ids[..1024.min(ids.len())].to_vec();
    let reps: u64 = if smoke { 4 } else { 40 };

    println!("\n== steady-state sampling, single thread, labor-1, {reps} batches");
    let mut scratch = SamplerScratch::for_vertices(graph.num_vertices());
    // warm up: size the arena to steady state before timing
    for b in 0..3u64 {
        std::hint::black_box(sampler.sample(&graph, &seeds, b, &mut scratch));
    }
    let t0 = Instant::now();
    for b in 0..reps {
        std::hint::black_box(sampler.sample(&graph, &seeds, b, &mut scratch));
    }
    let warm = t0.elapsed().as_secs_f64();
    println!("warm scratch : {:.1} batches/s", reps as f64 / warm);
    let t0 = Instant::now();
    for b in 0..reps {
        std::hint::black_box(sampler.sample_fresh(&graph, &seeds, b));
    }
    let fresh = t0.elapsed().as_secs_f64();
    println!("fresh scratch: {:.1} batches/s ({:.2}x)", reps as f64 / fresh, fresh / warm);

    // -- allocation probe ---------------------------------------------
    let probe = |label: &str, f: &mut dyn FnMut(u64)| {
        let n: u64 = if smoke { 3 } else { 10 };
        let (a0, b0) = counters();
        for b in 0..n {
            f(b);
        }
        let (a1, b1) = counters();
        println!(
            "{label}: {:.0} allocations / {:.1} KiB allocated per batch",
            (a1 - a0) as f64 / n as f64,
            (b1 - b0) as f64 / n as f64 / 1024.0
        );
    };
    println!(
        "\n== allocation probe, labor-1 3-layer, batch 1024, |V|={}",
        graph.num_vertices()
    );
    probe("warm scratch ", &mut |b| {
        std::hint::black_box(sampler.sample(&graph, &seeds, b, &mut scratch));
    });
    probe("fresh scratch", &mut |b| {
        std::hint::black_box(sampler.sample_fresh(&graph, &seeds, b));
    });
    println!(
        "(warm-scratch allocations are the MFG output vectors only — the \
         O(|V|) maps and every work buffer live in the arena)"
    );
}
