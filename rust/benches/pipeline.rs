//! Coordinator pipeline throughput: sampling workers + bounded queue, as a
//! function of worker count (the L3 §Perf scaling check).

use labor_gnn::coordinator::pipeline::{PipelineConfig, SamplingPipeline};
use labor_gnn::data::Dataset;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let ds = Arc::new(Dataset::load_or_generate("flickr-sim", 0.1).expect("dataset"));
    let graph = Arc::new(ds.graph.clone());
    let ids = Arc::new(ds.splits.train.clone());
    let batches = 60u64;

    println!("== pipeline throughput, labor-1, batch 1024, {batches} batches");
    for workers in [1usize, 2, 4, 8] {
        let sampler = Arc::new(MultiLayerSampler::new(
            SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false },
            &[10, 10, 10],
        ));
        let t0 = Instant::now();
        let mut p = SamplingPipeline::spawn(
            graph.clone(),
            sampler,
            ids.clone(),
            PipelineConfig {
                num_workers: workers,
                queue_depth: 8,
                batch_size: 1024,
                num_batches: batches,
                seed: 3,
            },
        );
        let mut n = 0;
        for b in &mut p {
            std::hint::black_box(b.mfg.vertex_counts());
            n += 1;
        }
        p.join();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "workers={workers}: {n} batches in {dt:.2}s = {:.1} batches/s",
            n as f64 / dt
        );
    }
}
