"""L2 model tests: shapes, losses, Adam, and the flat AOT calling
convention (train_step must behave identically through the flat interface
used by the Rust runtime)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    adam_init,
    adam_step,
    example_batch,
    flat_train_args,
    forward,
    init_params,
    loss_fn,
    make_forward,
    make_train_step,
    param_names,
)


def tiny_cfg(arch="gcn", multilabel=False):
    return ModelConfig(
        name="t",
        arch=arch,
        batch_size=8,
        k_max=4,
        v_caps=(24, 48, 96),
        num_features=6,
        hidden=16,
        num_classes=3,
        multilabel=multilabel,
        num_heads=2,
    )


class TestForward:
    @pytest.mark.parametrize("arch", ["gcn", "gatv2"])
    def test_logit_shapes(self, arch):
        cfg = tiny_cfg(arch)
        params = init_params(cfg)
        feats, idxs, ws, _, _ = example_batch(cfg)
        logits = forward(params, cfg, feats, idxs, ws)
        assert logits.shape == (8, 3)
        assert np.isfinite(np.array(logits)).all()

    def test_layer_rows_ordering(self):
        cfg = tiny_cfg()
        # compute order: deepest first — inputs 96 -> 48 -> 24 -> 8
        assert cfg.layer_rows() == [(96, 48), (48, 24), (24, 8)]

    def test_residual_path_matters(self):
        # zeroing the residual projection must change the output
        cfg = tiny_cfg()
        params = init_params(cfg)
        feats, idxs, ws, _, _ = example_batch(cfg)
        a = forward(params, cfg, feats, idxs, ws)
        params2 = dict(params, r1=jnp.zeros_like(params["r1"]))
        b = forward(params2, cfg, feats, idxs, ws)
        assert np.abs(np.array(a) - np.array(b)).max() > 1e-4


class TestLoss:
    def test_single_label_matches_manual_ce(self):
        cfg = tiny_cfg()
        params = init_params(cfg)
        feats, idxs, ws, labels, mask = example_batch(cfg)
        loss = loss_fn(params, cfg, feats, idxs, ws, labels, mask)
        logits = forward(params, cfg, feats, idxs, ws)
        logz = jax.nn.log_softmax(logits, -1)
        manual = -np.take_along_axis(np.array(logz), np.array(labels)[:, None], 1).mean()
        np.testing.assert_allclose(float(loss), manual, rtol=1e-5)

    def test_mask_excludes_padded_rows(self):
        cfg = tiny_cfg()
        params = init_params(cfg)
        feats, idxs, ws, labels, mask = example_batch(cfg)
        # corrupt the last row's label; with mask=0 there the loss must not move
        labels_bad = labels.at[-1].set((labels[-1] + 1) % 3)
        mask0 = mask.at[-1].set(0.0)
        l1 = loss_fn(params, cfg, feats, idxs, ws, labels, mask0)
        l2 = loss_fn(params, cfg, feats, idxs, ws, labels_bad, mask0)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)

    def test_multilabel_bce_bounds(self):
        cfg = tiny_cfg(multilabel=True)
        params = init_params(cfg)
        feats, idxs, ws, labels, mask = example_batch(cfg)
        loss = float(loss_fn(params, cfg, feats, idxs, ws, labels, mask))
        assert 0.0 < loss < 10.0


class TestAdam:
    def test_matches_reference_formula(self):
        params = {"w": jnp.array([1.0, 2.0])}
        grads = {"w": jnp.array([0.1, -0.2])}
        m, v, t = adam_init(params)
        p2, m2, v2, t2 = adam_step(params, grads, m, v, t, lr=0.01)
        # step 1: mhat = g, vhat = g^2  => update = lr * g / (|g| + eps)
        expect = np.array([1.0, 2.0]) - 0.01 * np.sign([0.1, -0.2])
        np.testing.assert_allclose(np.array(p2["w"]), expect, rtol=1e-4)
        assert float(t2) == 1.0

    def test_descends_quadratic(self):
        params = {"w": jnp.array([5.0])}
        m, v, t = adam_init(params)
        for _ in range(300):
            g = {"w": 2.0 * params["w"]}
            params, m, v, t = adam_step(params, g, m, v, t, lr=0.05)
        assert abs(float(params["w"][0])) < 0.5


class TestFlatConvention:
    def test_train_step_flat_roundtrip(self):
        cfg = tiny_cfg()
        params = init_params(cfg)
        m, v, t = adam_init(params)
        feats, idxs, ws, labels, mask = example_batch(cfg)
        args = flat_train_args(cfg, params, m, v, t, feats, idxs, ws, labels, mask)
        step = make_train_step(cfg)
        out = step(*args)
        names = param_names(cfg)
        n = len(names)
        assert len(out) == 3 * n + 2
        loss = out[-1]
        assert np.isfinite(float(loss))
        # params moved
        assert np.abs(np.array(out[names.index("w1")]) - np.array(params["w1"])).max() > 0

    def test_loss_decreases_over_flat_steps(self):
        cfg = tiny_cfg()
        params = init_params(cfg)
        m, v, t = adam_init(params)
        feats, idxs, ws, labels, mask = example_batch(cfg)
        step = jax.jit(make_train_step(cfg))
        names = param_names(cfg)
        n = len(names)
        losses = []
        for _ in range(30):
            args = flat_train_args(cfg, params, m, v, t, feats, idxs, ws, labels, mask, lr=0.01)
            out = step(*args)
            params = dict(zip(names, out[:n]))
            m = dict(zip(names, out[n : 2 * n]))
            v = dict(zip(names, out[2 * n : 3 * n]))
            t = out[3 * n]
            losses.append(float(out[-1]))
        assert losses[-1] < 0.5 * losses[0], losses

    def test_forward_flat_matches_direct(self):
        cfg = tiny_cfg()
        params = init_params(cfg)
        feats, idxs, ws, _, _ = example_batch(cfg)
        fwd = make_forward(cfg)
        names = param_names(cfg)
        args = [params[k] for k in names] + [feats]
        for i in range(3):
            args += [idxs[i], ws[i]]
        (flat_logits,) = fwd(*args)
        direct = forward(params, cfg, feats, idxs, ws)
        np.testing.assert_allclose(np.array(flat_logits), np.array(direct), rtol=1e-6)
