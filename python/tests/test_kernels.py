"""Kernel-vs-oracle correctness: Pallas kernels against the pure-jnp refs,
with hypothesis sweeps over shapes and dtypes (the core L1 signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.gat import gatv2_aggregate
from compile.kernels.ref import gatv2_ref, spmm_ref
from compile.kernels.spmm import spmm, vmem_estimate_bytes

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def make_spmm_case(rng, n, k, m, f, dtype=np.float32):
    idx = rng.integers(0, m, (n, k)).astype(np.int32)
    w = rng.random((n, k)).astype(dtype)
    feats = rng.standard_normal((m, f)).astype(dtype)
    return jnp.array(idx), jnp.array(w), jnp.array(feats)


class TestSpmm:
    def test_matches_ref_basic(self):
        idx, w, feats = make_spmm_case(np.random.default_rng(0), 37, 7, 50, 13)
        np.testing.assert_allclose(
            np.array(spmm(idx, w, feats)), np.array(spmm_ref(idx, w, feats)),
            rtol=1e-5, atol=1e-5,
        )

    def test_zero_weights_give_zero_rows(self):
        idx, w, feats = make_spmm_case(np.random.default_rng(1), 8, 4, 10, 5)
        w = w.at[3].set(0.0)
        out = np.array(spmm(idx, w, feats))
        np.testing.assert_allclose(out[3], np.zeros(5), atol=1e-7)

    def test_single_row_and_single_neighbor(self):
        idx, w, feats = make_spmm_case(np.random.default_rng(2), 1, 1, 3, 4)
        np.testing.assert_allclose(
            np.array(spmm(idx, w, feats)), np.array(spmm_ref(idx, w, feats)),
            rtol=1e-5, atol=1e-6,
        )

    def test_block_rows_variants_agree(self):
        idx, w, feats = make_spmm_case(np.random.default_rng(3), 33, 5, 40, 8)
        a = np.array(spmm(idx, w, feats, 4))
        b = np.array(spmm(idx, w, feats, 32))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_gradients_match_ref(self):
        idx, w, feats = make_spmm_case(np.random.default_rng(4), 12, 6, 20, 7)
        ga = jax.grad(lambda w, f: (spmm(idx, w, f) ** 2).sum(), argnums=(0, 1))(w, feats)
        gb = jax.grad(lambda w, f: (spmm_ref(idx, w, f) ** 2).sum(), argnums=(0, 1))(w, feats)
        for x, y in zip(ga, gb):
            np.testing.assert_allclose(np.array(x), np.array(y), rtol=1e-4, atol=1e-4)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis unavailable")
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 40),
        k=st.integers(1, 12),
        m=st.integers(1, 60),
        f=st.integers(1, 32),
        seed=st.integers(0, 2**31 - 1),
        dtype=st.sampled_from([np.float32, np.float64]),
    )
    def test_hypothesis_shape_dtype_sweep(self, n, k, m, f, seed, dtype):
        idx, w, feats = make_spmm_case(np.random.default_rng(seed), n, k, m, f, dtype)
        tol = 1e-5 if dtype == np.float32 else 1e-10
        np.testing.assert_allclose(
            np.array(spmm(idx, w, feats)), np.array(spmm_ref(idx, w, feats)),
            rtol=tol * 10, atol=tol,
        )

    def test_vmem_estimate_monotone(self):
        assert vmem_estimate_bytes(16, 20, 602) > vmem_estimate_bytes(8, 20, 602)
        assert vmem_estimate_bytes(16, 20, 602) < 16 * 1024 * 1024  # fits VMEM


def make_gat_case(rng, n, k, m, hd, d):
    idx = rng.integers(0, m, (n, k)).astype(np.int32)
    mask = (rng.random((n, k)) < 0.7).astype(np.float32)
    mask[:, 0] = 1.0  # at least one live edge per row
    h_src = rng.standard_normal((m, hd, d)).astype(np.float32)
    h_dst = rng.standard_normal((n, hd, d)).astype(np.float32)
    att = rng.standard_normal((hd, d)).astype(np.float32)
    return tuple(map(jnp.array, (idx, mask, h_src, h_dst, att)))


class TestGat:
    def test_matches_ref_basic(self):
        case = make_gat_case(np.random.default_rng(0), 19, 6, 30, 4, 8)
        np.testing.assert_allclose(
            np.array(gatv2_aggregate(*case)), np.array(gatv2_ref(*case)),
            rtol=2e-5, atol=2e-5,
        )

    def test_fully_masked_rows_do_not_nan(self):
        idx, mask, h_src, h_dst, att = make_gat_case(np.random.default_rng(1), 6, 4, 10, 2, 4)
        mask = mask.at[2].set(0.0)
        out = np.array(gatv2_aggregate(idx, mask, h_src, h_dst, att))
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out[2], 0.0, atol=1e-6)

    def test_attention_is_convex_combination(self):
        # with all-ones mask, output of each head lies in the convex hull of
        # gathered neighbors: max |out| <= max |h_src|
        case = make_gat_case(np.random.default_rng(2), 10, 5, 15, 2, 6)
        idx, mask, h_src, h_dst, att = case
        mask = jnp.ones_like(mask)
        out = np.array(gatv2_aggregate(idx, mask, h_src, h_dst, att))
        assert np.abs(out).max() <= np.abs(np.array(h_src)).max() + 1e-5

    def test_gradients_match_ref(self):
        idx, mask, h_src, h_dst, att = make_gat_case(np.random.default_rng(3), 7, 4, 12, 2, 4)
        ga = jax.grad(
            lambda hs, hd, a: (gatv2_aggregate(idx, mask, hs, hd, a) ** 2).sum(),
            argnums=(0, 1, 2),
        )(h_src, h_dst, att)
        gb = jax.grad(
            lambda hs, hd, a: (gatv2_ref(idx, mask, hs, hd, a) ** 2).sum(),
            argnums=(0, 1, 2),
        )(h_src, h_dst, att)
        for x, y in zip(ga, gb):
            np.testing.assert_allclose(np.array(x), np.array(y), rtol=1e-4, atol=1e-4)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis unavailable")
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(1, 20),
        k=st.integers(1, 8),
        m=st.integers(1, 30),
        hd=st.integers(1, 4),
        d=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, n, k, m, hd, d, seed):
        case = make_gat_case(np.random.default_rng(seed), n, k, m, hd, d)
        np.testing.assert_allclose(
            np.array(gatv2_aggregate(*case)), np.array(gatv2_ref(*case)),
            rtol=5e-5, atol=5e-5,
        )
