"""Layer-1 Pallas kernel: padded-neighborhood gather-SpMM.

This is the compute hot-spot of sampled GNN aggregation (Eq. 2 of the
paper, restricted to the sampled subgraph): for each output vertex `n`,

    out[n] = sum_k  w[n, k] * feats[idx[n, k]]

Hardware adaptation (DESIGN.md §Hardware-Adaptation): GPU implementations
(DGL/cuSPARSE) scatter per-edge with atomics; TPUs have no atomics, so we
use the *gather* formulation over the sampler's fixed-K padded neighbor
layout. The grid tiles output rows; each grid step gathers a
`(BN, K, F)` window of source rows into VMEM and contracts K on the
VPU/MXU. The features table stays un-tiled (ANY/HBM) and is gathered
per block.

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel lowers to plain HLO while keeping the
exact block/grid structure a TPU build would use (VMEM/MXU estimates in
DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(idx_ref, w_ref, feats_ref, o_ref):
    """One grid step: produce a (BN, F) tile of output rows."""
    idx = idx_ref[...]  # (BN, K) i32
    w = w_ref[...]  # (BN, K) f32
    gathered = feats_ref[idx]  # (BN, K, F) gather from full table
    # contract K: (BN, K) x (BN, K, F) -> (BN, F)
    o_ref[...] = jnp.einsum(
        "nk,nkf->nf", w, gathered, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def auto_block_rows(k: int, f: int, budget_bytes: int = 8 << 20) -> int:
    """Pick the output-row tile so the gathered (BN, K, F) window fits the
    memory budget (~8 MiB: half of TPU VMEM, and near the CPU LLC sweet
    spot — §Perf measured 2.2x over BN=16 on flickr-sim shapes)."""
    bn = budget_bytes // max(1, 4 * k * f)
    return max(64, min(512, int(bn)))


def _spmm_pallas(idx, w, feats, block_rows):
    n, _k = idx.shape
    _m, f = feats.shape
    if block_rows is None:
        block_rows = auto_block_rows(idx.shape[1], f)
    bn = min(block_rows, n)
    grid = (pl.cdiv(n, bn),)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, idx.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bn, idx.shape[1]), lambda i: (i, 0)),
            # full feature table visible to every grid step (gathers)
            pl.BlockSpec(feats.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), feats.dtype),
        interpret=True,
    )(idx, w, feats)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def spmm(idx, w, feats, block_rows=None):
    """Pallas gather-SpMM. See module docstring.

    Differentiable in ``w`` and ``feats``: interpret-mode ``pallas_call``
    does not support reverse-mode autodiff, so the backward pass is the VJP
    of the pure-jnp oracle (same math: gather-dot for ``w``, scatter-add
    for ``feats``). The forward hot path stays on the Pallas kernel.

    Args:
      idx: i32[N, K] neighbor indices into ``feats`` rows.
      w: f32[N, K] edge weights (0 for padding).
      feats: f32[M, F] source rows.
      block_rows: output rows per grid step (BN); `None` = auto-tile to the
        ~8 MiB window budget (see `auto_block_rows`). N must not be 0.

    Returns: f32[N, F].
    """
    return _spmm_pallas(idx, w, feats, block_rows)


def _spmm_fwd(idx, w, feats, block_rows):
    return _spmm_pallas(idx, w, feats, block_rows), (idx, w, feats)


def _spmm_bwd(_block_rows, res, g):
    from .ref import spmm_ref

    idx, w, feats = res
    _, vjp = jax.vjp(lambda ww, ff: spmm_ref(idx, ww, ff), w, feats)
    gw, gf = vjp(g)
    return None, gw, gf


spmm.defvjp(_spmm_fwd, _spmm_bwd)


def vmem_estimate_bytes(n_block: int, k: int, f: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid step on a real TPU.

    idx + w tiles, the gathered (BN, K, F) window, and the (BN, F) output
    tile. Used by DESIGN.md §Perf to choose ``block_rows`` such that the
    working set fits in ~16 MiB of VMEM.
    """
    idx_w = 2 * n_block * k * dtype_bytes
    gathered = n_block * k * f * dtype_bytes
    out = n_block * f * dtype_bytes
    return idx_w + gathered + out
