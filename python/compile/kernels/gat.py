"""Layer-1 Pallas kernel: padded-neighborhood GATv2 attention aggregation.

Backs the GATv2 runtime experiment (paper Appendix A.6 / Table 5). Same
gather-window strategy as ``spmm.py``: the grid tiles output rows, each
step gathers the (BN, K, Hd, D) window of projected source features,
computes GATv2 attention logits, masks padding, softmaxes over K, and
contracts K.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gat_kernel(idx_ref, mask_ref, hsrc_ref, hdst_ref, att_ref, o_ref, *, slope):
    idx = idx_ref[...]  # (BN, K)
    mask = mask_ref[...]  # (BN, K)
    g = hsrc_ref[idx]  # (BN, K, Hd, D)
    z = g + hdst_ref[...][:, None, :, :]
    z = jnp.where(z >= 0, z, slope * z)
    e = jnp.einsum("nkhd,hd->nkh", z, att_ref[...])
    neg = jnp.finfo(e.dtype).min
    e = jnp.where(mask[:, :, None] > 0, e, neg)
    alpha = jnp.exp(e - e.max(axis=1, keepdims=True))
    alpha = alpha * mask[:, :, None]
    denom = jnp.maximum(alpha.sum(axis=1, keepdims=True), 1e-12)
    alpha = alpha / denom
    o_ref[...] = jnp.einsum(
        "nkh,nkhd->nhd", alpha, g, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _gat_pallas(idx, mask, h_src, h_dst, att, block_rows, slope: float):
    n, k = idx.shape
    _, hd, d = h_dst.shape
    if block_rows is None:
        from .spmm import auto_block_rows

        block_rows = auto_block_rows(k, hd * d)
    bn = min(block_rows, n)
    grid = (pl.cdiv(n, bn),)
    kernel = functools.partial(_gat_kernel, slope=slope)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec(h_src.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((bn, hd, d), lambda i: (i, 0, 0)),
            pl.BlockSpec(att.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, hd, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, hd, d), h_src.dtype),
        interpret=True,
    )(idx, mask, h_src, h_dst, att)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def gatv2_aggregate(idx, mask, h_src, h_dst, att, block_rows=None, slope: float = 0.2):
    """Pallas GATv2 aggregation; see ``ref.gatv2_ref`` for semantics.

    Differentiable in ``mask``/``h_src``/``h_dst``/``att``: the backward
    pass is the VJP of the pure-jnp oracle (interpret-mode ``pallas_call``
    has no reverse-mode autodiff); forward stays on the Pallas kernel.

    Args:
      idx:   i32[N, K] neighbor indices into ``h_src``.
      mask:  f32[N, K] 1 = real edge, 0 = padding.
      h_src: f32[M, Hd, D] projected source features.
      h_dst: f32[N, Hd, D] projected destination features.
      att:   f32[Hd, D] attention vectors.

    Returns: f32[N, Hd, D].
    """
    return _gat_pallas(idx, mask, h_src, h_dst, att, block_rows, slope)


def _gat_fwd(idx, mask, h_src, h_dst, att, block_rows, slope):
    out = _gat_pallas(idx, mask, h_src, h_dst, att, block_rows, slope)
    return out, (idx, mask, h_src, h_dst, att)


def _gat_bwd(_block_rows, slope, res, g):
    from .ref import gatv2_ref

    idx, mask, h_src, h_dst, att = res
    _, vjp = jax.vjp(
        lambda hs, hd, a: gatv2_ref(idx, mask, hs, hd, a, slope), h_src, h_dst, att
    )
    ghs, ghd, gatt = vjp(g)
    return None, None, ghs, ghd, gatt


gatv2_aggregate.defvjp(_gat_fwd, _gat_bwd)
