"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float32 tolerance for all shapes/dtypes covered by
``python/tests`` (hypothesis sweeps).
"""

import jax.numpy as jnp


def spmm_ref(idx, w, feats):
    """Padded-neighborhood gather-SpMM reference.

    out[n] = sum_k w[n, k] * feats[idx[n, k]]

    Args:
      idx: i32[N, K] neighbor row indices into ``feats`` (padding may point
        anywhere valid; its weight must be 0).
      w:   f32[N, K] edge weights (Hajek-normalized by the sampler).
      feats: f32[M, F] input rows.

    Returns: f32[N, F].
    """
    gathered = feats[idx]  # [N, K, F]
    return jnp.einsum("nk,nkf->nf", w, gathered)


def gatv2_ref(idx, mask, h_src, h_dst, att, slope: float = 0.2):
    """Padded-neighborhood GATv2 attention aggregation reference.

    Per head h:
      e[n,k,h]  = att[h] . leaky_relu(h_src[idx[n,k],h] + h_dst[n,h])
      alpha     = softmax_k(e) restricted to mask
      out[n,h]  = sum_k alpha[n,k,h] * h_src[idx[n,k],h]

    Args:
      idx:   i32[N, K] neighbor row indices into ``h_src``.
      mask:  f32[N, K] 1 for real edges, 0 for padding.
      h_src: f32[M, Hd, D] projected source features (W_s x).
      h_dst: f32[N, Hd, D] projected destination features (W_d x).
      att:   f32[Hd, D] attention vectors.

    Returns: f32[N, Hd, D].
    """
    g = h_src[idx]  # [N, K, Hd, D]
    z = g + h_dst[:, None, :, :]
    z = jnp.where(z >= 0, z, slope * z)  # LeakyReLU
    e = jnp.einsum("nkhd,hd->nkh", z, att)
    neg = jnp.finfo(e.dtype).min
    e = jnp.where(mask[:, :, None] > 0, e, neg)
    alpha = jnp.exp(e - e.max(axis=1, keepdims=True))
    alpha = alpha * mask[:, :, None]
    denom = alpha.sum(axis=1, keepdims=True)
    alpha = alpha / jnp.maximum(denom, 1e-12)
    return jnp.einsum("nkh,nkhd->nhd", alpha, g)
