"""Artifact configurations: one compiled (train_step, forward) pair per
dataset x architecture.

The padded vertex caps (V1, V2, V3) bound the per-layer input row counts of
a sampled MFG. They were calibrated with ``repro calibrate-caps`` (p99 over
NS batches — NS samples the most vertices of all methods, so its caps cover
every sampler) at the default experiment settings: dataset scale 0.1,
batch 1024, fanout 10. The Rust runtime re-checks every batch against the
manifest and fails loudly if a cap is exceeded.

K_MAX is 2x fanout: LABOR guarantees E[d_s] >= min(k, d_s) and importance
sampling pushes some expected degrees above k; overflow beyond K_MAX is
dropped with weight renormalization on the Rust side (documented
approximation, DESIGN.md section 2 — never affects sampler statistics).
"""

from .model import ModelConfig

# (V1, V2, V3) caps per dataset at scale 0.1, batch 1024, fanout 10 —
# measured with `repro calibrate-caps` (NS max over batches * 1.15,
# clipped at |V|).
_CAPS = {
    "reddit-sim": (9_584, 23_300, 23_300),  # |V| = 23.3k: caps clip at |V|
    "products-sim": (9_826, 58_413, 180_885),
    "yelp-sim": (8_289, 35_606, 69_704),
    "flickr-sim": (3_901, 7_311, 8_905),  # |V| = 8.9k
    "tiny": (3_100, 3_100, 3_100),
}

_FEATURES = {"reddit-sim": 602, "products-sim": 100, "yelp-sim": 300, "flickr-sim": 500, "tiny": 16}
_CLASSES = {"reddit-sim": 41, "products-sim": 47, "yelp-sim": 50, "flickr-sim": 7, "tiny": 4}
_MULTILABEL = {"yelp-sim"}

BATCH_SIZE = 1024
K_MAX = 20
HIDDEN = 64  # paper uses 256; 64 keeps the CPU-only experiment grid tractable


def make_config(dataset: str, arch: str = "gcn", hidden: int = HIDDEN,
                batch_size: int = BATCH_SIZE, k_max: int = K_MAX) -> ModelConfig:
    caps = _CAPS[dataset]
    return ModelConfig(
        name=f"{arch}_{dataset}",
        arch=arch,
        batch_size=batch_size,
        k_max=k_max,
        v_caps=caps,
        num_features=_FEATURES[dataset],
        hidden=hidden,
        num_classes=_CLASSES[dataset],
        multilabel=dataset in _MULTILABEL,
    )


# what `make artifacts` builds by default: the GCN for every dataset + the
# GATv2 for the Table 5 experiment on the two smaller datasets
DEFAULT_BUILDS = [
    ("tiny", "gcn"),
    ("flickr-sim", "gcn"),
    ("yelp-sim", "gcn"),
    ("reddit-sim", "gcn"),
    ("products-sim", "gcn"),
    ("flickr-sim", "gatv2"),
    ("tiny", "gatv2"),
]
