"""Layer-2: the paper's models (GCN §4, GATv2 A.6) in JAX, plus losses and
a hand-rolled Adam — everything that gets AOT-lowered into a single
``train_step`` / ``forward`` HLO per dataset configuration.

Batch layout (static shapes, chosen in ``configs.py``): the Rust
coordinator packs each sampled MFG into the fixed *padded-neighborhood*
format — per GNN layer `l` (compute order: deepest first),

    idx_l: i32[V_{out,l}, K]   neighbor row indices into layer input rows
    w_l:   f32[V_{out,l}, K]   Hajek edge weights (0 = padding)

with the convention that layer input rows start with the layer's output
(seed) rows, so residual/self connections are realized by slicing the
prefix. Padded vertices carry zero features/weights and are masked out of
the loss.
"""

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.gat import gatv2_aggregate
from .kernels.spmm import spmm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static shape + architecture description of one compiled artifact."""

    name: str
    arch: str  # "gcn" | "gatv2"
    batch_size: int  # B: number of (padded) seed rows
    k_max: int  # K: padded per-vertex neighbor budget
    v_caps: Tuple[int, ...]  # (V1, V2, V3): padded row counts per depth
    num_features: int
    hidden: int
    num_classes: int
    multilabel: bool
    num_heads: int = 8  # GATv2 only
    lr: float = 1e-3

    @property
    def num_layers(self) -> int:
        return len(self.v_caps)

    def layer_rows(self) -> List[Tuple[int, int]]:
        """(input_rows, output_rows) per GNN layer in compute order."""
        dims = list(self.v_caps)[::-1] + [self.batch_size]
        return [(dims[i], dims[i + 1]) for i in range(len(dims) - 1)]


# ---------------------------------------------------------------------------
# parameters


def glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -scale, scale)


def init_gcn_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """3-layer GCN with residual skip connections (paper §4)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 8)
    f, h, c = cfg.num_features, cfg.hidden, cfg.num_classes
    return {
        "w1": glorot(keys[0], (f, h)),
        "b1": jnp.zeros((h,), jnp.float32),
        "r1": glorot(keys[1], (f, h)),  # residual projection F -> H
        "w2": glorot(keys[2], (h, h)),
        "b2": jnp.zeros((h,), jnp.float32),
        "w3": glorot(keys[3], (h, c)),
        "b3": jnp.zeros((c,), jnp.float32),
    }


def init_gatv2_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """3-layer GATv2 (paper A.6), ``num_heads`` heads, concat between
    layers, mean over heads at the output layer."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 16)
    f, h, c, hd = cfg.num_features, cfg.hidden, cfg.num_classes, cfg.num_heads
    d = h // hd
    assert h % hd == 0, "hidden must divide num_heads"
    return {
        "ws1": glorot(keys[0], (f, hd * d)),
        "wd1": glorot(keys[1], (f, hd * d)),
        "a1": glorot(keys[2], (hd, d)),
        "ws2": glorot(keys[3], (h, hd * d)),
        "wd2": glorot(keys[4], (h, hd * d)),
        "a2": glorot(keys[5], (hd, d)),
        "ws3": glorot(keys[6], (h, hd * c)),
        "wd3": glorot(keys[7], (h, hd * c)),
        "a3": glorot(keys[8], (hd, c)),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    if cfg.arch == "gcn":
        return init_gcn_params(cfg, seed)
    if cfg.arch == "gatv2":
        return init_gatv2_params(cfg, seed)
    raise ValueError(f"unknown arch {cfg.arch}")


# ---------------------------------------------------------------------------
# forward passes


def gcn_forward(params, cfg: ModelConfig, feats, idxs, ws):
    """feats: f32[V_deepest, F]; idxs/ws: lists in compute order."""
    rows = cfg.layer_rows()

    # layer 1: F -> H (relu, residual projection)
    (_, out1) = rows[0]
    agg = spmm(idxs[0], ws[0], feats)  # [V2, F]
    res = feats[:out1] @ params["r1"]
    h = jax.nn.relu(agg @ params["w1"] + params["b1"] + res)

    # layer 2: H -> H (relu, identity residual)
    (_, out2) = rows[1]
    agg = spmm(idxs[1], ws[1], h)
    h = jax.nn.relu(agg @ params["w2"] + params["b2"] + h[:out2])

    # layer 3: H -> C (linear head)
    agg = spmm(idxs[2], ws[2], h)
    logits = agg @ params["w3"] + params["b3"]
    return logits


def _gat_layer(x, idx, w, ws_p, wd_p, att, out_rows, hd):
    """One GATv2 layer over the padded-neighborhood block."""
    m = x.shape[0]
    d = ws_p.shape[1] // hd
    h_src = (x @ ws_p).reshape(m, hd, d)
    h_dst = (x[:out_rows] @ wd_p).reshape(out_rows, hd, d)
    mask = (w > 0).astype(x.dtype)
    out = gatv2_aggregate(idx, mask, h_src, h_dst, att)  # [out, Hd, D]
    return out


def gatv2_forward(params, cfg: ModelConfig, feats, idxs, ws):
    rows = cfg.layer_rows()
    hd = cfg.num_heads

    (_, out1) = rows[0]
    h = _gat_layer(feats, idxs[0], ws[0], params["ws1"], params["wd1"], params["a1"], out1, hd)
    h = jax.nn.elu(h.reshape(out1, -1))  # concat heads

    (_, out2) = rows[1]
    h = _gat_layer(h, idxs[1], ws[1], params["ws2"], params["wd2"], params["a2"], out2, hd)
    h = jax.nn.elu(h.reshape(out2, -1))

    (_, out3) = rows[2]
    o = _gat_layer(h, idxs[2], ws[2], params["ws3"], params["wd3"], params["a3"], out3, hd)
    return o.mean(axis=1)  # mean over heads -> [B, C]


def forward(params, cfg: ModelConfig, feats, idxs, ws):
    if cfg.arch == "gcn":
        return gcn_forward(params, cfg, feats, idxs, ws)
    return gatv2_forward(params, cfg, feats, idxs, ws)


# ---------------------------------------------------------------------------
# losses


def loss_fn(params, cfg: ModelConfig, feats, idxs, ws, labels, mask):
    """Masked mean loss over the (padded) seed rows.

    Single-label: softmax cross-entropy, ``labels: i32[B]``.
    Multilabel:   sigmoid BCE, ``labels: f32[B, C]``.
    """
    logits = forward(params, cfg, feats, idxs, ws)
    if cfg.multilabel:
        logp = jax.nn.log_sigmoid(logits)
        lognp = jax.nn.log_sigmoid(-logits)
        per = -(labels * logp + (1.0 - labels) * lognp).mean(axis=-1)
    else:
        logz = jax.nn.log_softmax(logits, axis=-1)
        per = -jnp.take_along_axis(logz, labels[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    return (per * mask).sum() / denom


# ---------------------------------------------------------------------------
# Adam (hand-rolled so the whole optimizer lowers into the same HLO)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params), jnp.zeros((), jnp.float32)


def adam_step(params, grads, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = t + 1.0
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    def upd(p, mm, vv):
        mhat = mm / bc1
        vhat = vv / bc2
        return p - lr * mhat / (jnp.sqrt(vhat) + eps)

    params = jax.tree_util.tree_map(upd, params, m, v)
    return params, m, v, t


# ---------------------------------------------------------------------------
# the two AOT entry points


def param_names(cfg: ModelConfig) -> List[str]:
    """Deterministic parameter ordering for the flat PJRT calling
    convention (sorted dict order, matching jax pytree flattening)."""
    return sorted(init_params(cfg).keys())


def make_train_step(cfg: ModelConfig):
    """Returns ``train_step(flat_args...) -> (new_params..., m..., v..., t,
    loss)`` over flat, deterministically-ordered tensors — the exact
    artifact signature the Rust runtime calls.

    Flat input order:
      params (sorted), m (sorted), v (sorted), t,
      feats, idx1, w1, idx2, w2, idx3, w3, labels, mask, lr

    ``lr`` is a runtime scalar input (not a baked constant) so the
    hyperparameter-tuning experiment (paper A.8 / Figure 4) can sweep it
    without recompiling artifacts.
    """
    names = param_names(cfg)
    npar = len(names)

    def train_step(*args):
        params = dict(zip(names, args[:npar]))
        m = dict(zip(names, args[npar : 2 * npar]))
        v = dict(zip(names, args[2 * npar : 3 * npar]))
        t = args[3 * npar]
        feats = args[3 * npar + 1]
        idxs = [args[3 * npar + 2], args[3 * npar + 4], args[3 * npar + 6]]
        ws = [args[3 * npar + 3], args[3 * npar + 5], args[3 * npar + 7]]
        labels = args[3 * npar + 8]
        mask = args[3 * npar + 9]
        lr = args[3 * npar + 10]

        loss, grads = jax.value_and_grad(loss_fn)(
            params, cfg, feats, idxs, ws, labels, mask
        )
        params, m, v, t = adam_step(params, grads, m, v, t, lr)
        out = [params[n] for n in names]
        out += [m[n] for n in names]
        out += [v[n] for n in names]
        out += [t, loss]
        return tuple(out)

    return train_step


def make_forward(cfg: ModelConfig):
    """Returns ``fwd(params..., feats, idx1, w1, idx2, w2, idx3, w3) ->
    (logits,)`` for evaluation."""
    names = param_names(cfg)
    npar = len(names)

    def fwd(*args):
        params = dict(zip(names, args[:npar]))
        feats = args[npar]
        idxs = [args[npar + 1], args[npar + 3], args[npar + 5]]
        ws = [args[npar + 2], args[npar + 4], args[npar + 6]]
        return (forward(params, cfg, feats, idxs, ws),)

    return fwd


def example_batch(cfg: ModelConfig, seed: int = 0):
    """Random example batch with the artifact's exact shapes (for lowering
    and for tests)."""
    rng = jax.random.PRNGKey(seed)
    ks = jax.random.split(rng, 12)
    rows = cfg.layer_rows()
    vin = rows[0][0]
    feats = jax.random.normal(ks[0], (vin, cfg.num_features), jnp.float32)
    idxs, ws = [], []
    for li, (r_in, r_out) in enumerate(rows):
        idx = jax.random.randint(ks[1 + li], (r_out, cfg.k_max), 0, r_in, jnp.int32)
        w = jax.random.uniform(ks[4 + li], (r_out, cfg.k_max), jnp.float32)
        w = w / w.sum(axis=1, keepdims=True)
        idxs.append(idx)
        ws.append(w)
    if cfg.multilabel:
        labels = (
            jax.random.uniform(ks[7], (cfg.batch_size, cfg.num_classes)) < 0.2
        ).astype(jnp.float32)
    else:
        labels = jax.random.randint(
            ks[7], (cfg.batch_size,), 0, cfg.num_classes, jnp.int32
        )
    mask = jnp.ones((cfg.batch_size,), jnp.float32)
    return feats, idxs, ws, labels, mask


def flat_train_args(cfg: ModelConfig, params, m, v, t, feats, idxs, ws, labels, mask,
                    lr=None):
    names = param_names(cfg)
    out = [params[n] for n in names]
    out += [m[n] for n in names]
    out += [v[n] for n in names]
    out += [t, feats]
    for i in range(3):
        out += [idxs[i], ws[i]]
    out += [labels, mask]
    out += [jnp.float32(cfg.lr if lr is None else lr)]
    return out
