"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for the Rust
runtime (L3).

HLO *text* is the interchange format, NOT serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only gcn_tiny]

Emits per config:
    artifacts/<name>.train.hlo.txt
    artifacts/<name>.fwd.hlo.txt
and a single artifacts/manifest.json describing every artifact's shapes
and flat calling convention (consumed by rust/src/runtime/manifest.rs).
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .configs import DEFAULT_BUILDS, make_config
from .model import (
    ModelConfig,
    adam_init,
    example_batch,
    flat_train_args,
    init_params,
    make_forward,
    make_train_step,
    param_names,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(x) -> dict:
    return {"dtype": str(x.dtype), "shape": list(x.shape)}


def lower_config(cfg: ModelConfig, out_dir: str) -> dict:
    """Lower train_step + forward for one config; return its manifest."""
    params = init_params(cfg)
    m, v, t = adam_init(params)
    feats, idxs, ws, labels, mask = example_batch(cfg)
    train_args = flat_train_args(cfg, params, m, v, t, feats, idxs, ws, labels, mask)

    train_step = make_train_step(cfg)
    lowered = jax.jit(train_step).lower(*train_args)
    train_path = os.path.join(out_dir, f"{cfg.name}.train.hlo.txt")
    with open(train_path, "w") as f:
        f.write(to_hlo_text(lowered))

    fwd = make_forward(cfg)
    names = param_names(cfg)
    fwd_args = [params[n] for n in names] + [feats]
    for i in range(3):
        fwd_args += [idxs[i], ws[i]]
    lowered_fwd = jax.jit(fwd).lower(*fwd_args)
    fwd_path = os.path.join(out_dir, f"{cfg.name}.fwd.hlo.txt")
    with open(fwd_path, "w") as f:
        f.write(to_hlo_text(lowered_fwd))

    return {
        "name": cfg.name,
        "arch": cfg.arch,
        "batch_size": cfg.batch_size,
        "k_max": cfg.k_max,
        "v_caps": list(cfg.v_caps),
        "num_features": cfg.num_features,
        "hidden": cfg.hidden,
        "num_classes": cfg.num_classes,
        "multilabel": cfg.multilabel,
        "lr": cfg.lr,
        "param_names": names,
        "param_shapes": {n: _shape_entry(params[n]) for n in names},
        "train_artifact": os.path.basename(train_path),
        "fwd_artifact": os.path.basename(fwd_path),
        # flat calling convention documentation (runtime asserts against it)
        "train_num_inputs": len(train_args),
        "train_num_outputs": 3 * len(names) + 2,
        "fwd_num_inputs": len(fwd_args),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="build a single config by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"configs": []}
    for dataset, arch in DEFAULT_BUILDS:
        cfg = make_config(dataset, arch)
        if args.only and cfg.name != args.only:
            continue
        print(f"lowering {cfg.name} (V caps {cfg.v_caps}, K {cfg.k_max}) ...", flush=True)
        manifest["configs"].append(lower_config(cfg, args.out_dir))

    man_path = os.path.join(args.out_dir, "manifest.json")
    # merge with an existing manifest when building a subset
    if args.only and os.path.exists(man_path):
        with open(man_path) as f:
            old = json.load(f)
        keep = [c for c in old.get("configs", []) if all(c["name"] != n["name"] for n in manifest["configs"])]
        manifest["configs"] = keep + manifest["configs"]
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {man_path} with {len(manifest['configs'])} configs")


if __name__ == "__main__":
    main()
