# Entry points for the LABOR reproduction. See README.md.

.PHONY: artifacts build test ci clean

# AOT-lower the JAX/Pallas model (L2+L1) to HLO text + manifest.json for
# the Rust runtime. Needs a Python environment with JAX installed.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

build:
	cargo build --release

test:
	cargo test -q

ci:
	./ci.sh

clean:
	cargo clean
	rm -rf artifacts results
