//! Offline stand-in for the crates.io `anyhow` crate.
//!
//! The build environment for this reproduction has no network access, so
//! this crate re-implements exactly the subset of `anyhow`'s API that
//! `labor-gnn` uses — [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and the [`Context`] extension trait. The semantics
//! match the real crate for that subset (context wraps and becomes the
//! `Display` message; the original error is kept as the source chain, shown
//! by `Debug`), so swapping the real `anyhow` back in is a Cargo.toml-only
//! change.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with an optional source chain.
///
/// Like the real `anyhow::Error`, this type deliberately does **not**
/// implement [`std::error::Error`] — that is what allows the blanket
/// `impl From<E: std::error::Error>` below to coexist with the standard
/// library's reflexive `impl From<T> for T`.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message. The wrapped error
    /// stays a real `source()` link, so `Debug` prints each chain level
    /// separately (matching the real `anyhow`'s "Caused by" output shape).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: context.to_string(),
            source: Some(Box::new(ChainLink { msg: self.msg, source: self.source })),
        }
    }

    /// The source chain root, as a plain `std::error::Error` trait object
    /// (the annotated closure return type drops the `Send + Sync` bounds).
    fn source_dyn(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| -> &(dyn StdError + 'static) { e })
    }
}

/// Internal adapter: a demoted [`Error`] level that participates in a real
/// `std::error::Error` source chain (so context nesting keeps every level).
struct ChainLink {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl fmt::Display for ChainLink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for ChainLink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl StdError for ChainLink {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| -> &(dyn StdError + 'static) { e })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source_dyn();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: context.to_string(), source: Some(Box::new(e)) })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: f().to_string(), source: Some(Box::new(e)) })
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string (inline captures supported).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_becomes_display_and_debug_keeps_chain() {
        let e: Result<()> = fails_io().context("reading manifest");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn nested_context_keeps_every_level() {
        let e = fails_io()
            .context("parsing HLO")
            .unwrap_err()
            .context("loading model");
        assert_eq!(e.to_string(), "loading model");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("parsing HLO"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let v = ok.with_context(unreachable_message).unwrap();
        assert_eq!(v, 7);

        fn unreachable_message() -> String {
            panic!("must not be evaluated on the Ok path")
        }
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("unknown dataset '{name}'");
        assert_eq!(e.to_string(), "unknown dataset 'x'");

        fn guarded(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            Ok(1)
        }
        assert!(guarded(true).is_ok());
        assert_eq!(guarded(false).unwrap_err().to_string(), "flag was false");

        fn bails() -> Result<()> {
            bail!("nope");
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope");
    }
}
