//! Offline stand-in for the `xla` crate (Rust bindings to xla_extension /
//! PJRT, as used by the real runtime — see `rust/src/runtime/mod.rs`).
//!
//! The build environment has no network access and no xla_extension
//! shared library, so this crate provides:
//!
//! * **Fully functional host-side [`Literal`]s** — shape-carrying typed
//!   buffers with `create_from_shape` / `copy_raw_from` / `to_vec` /
//!   `scalar` / tuple accessors. Everything in `runtime::tensor`,
//!   `runtime::packer` and `train::state` works for real against these.
//! * **Structural PJRT types** ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`HloModuleProto`], [`XlaComputation`]) whose *execution* entry points
//!   return a clear [`Error`] instead of running HLO. All integration tests
//!   and binaries gate execution behind `Manifest::load("artifacts")`, so
//!   in a checkout without AOT artifacts nothing ever reaches `execute`.
//!
//! Swapping the real bindings back in is a Cargo.toml-only change: the
//! signatures below mirror the real crate for the subset labor-gnn uses.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type for all fallible operations in this crate.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias with this crate's [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

/// XLA primitive element types (subset used by the runtime).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    S32,
}

impl PrimitiveType {
    /// Size of one element in bytes.
    pub fn byte_size(self) -> usize {
        match self {
            PrimitiveType::F32 | PrimitiveType::S32 => 4,
        }
    }
}

/// Host-side element types, convertible to [`PrimitiveType`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    S32,
}

impl ElementType {
    /// The on-device primitive type for this element type.
    pub fn primitive_type(self) -> PrimitiveType {
        match self {
            ElementType::F32 => PrimitiveType::F32,
            ElementType::S32 => PrimitiveType::S32,
        }
    }
}

/// Rust native types that map onto an XLA [`PrimitiveType`].
pub trait NativeType: Copy {
    /// The corresponding XLA primitive type.
    const PRIMITIVE_TYPE: PrimitiveType;

    /// Serialize one value into little-endian bytes.
    fn write_le(self, out: &mut Vec<u8>);

    /// Deserialize one value from little-endian bytes.
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const PRIMITIVE_TYPE: PrimitiveType = PrimitiveType::F32;

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().expect("4-byte f32"))
    }
}

impl NativeType for i32 {
    const PRIMITIVE_TYPE: PrimitiveType = PrimitiveType::S32;

    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes(bytes.try_into().expect("4-byte i32"))
    }
}

/// A host literal: a typed, shaped buffer, or a tuple of literals.
///
/// This is the one part of the stand-in that is fully functional — the
/// packer and parameter-state layers build and read literals for real.
#[derive(Clone, Debug)]
pub enum Literal {
    /// A dense array with row-major little-endian storage.
    Array {
        /// element type
        ty: PrimitiveType,
        /// dimensions (row-major)
        dims: Vec<usize>,
        /// raw little-endian bytes, `dims.product() * ty.byte_size()` long
        data: Vec<u8>,
    },
    /// A tuple of literals (the result convention of compiled functions).
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Zero-initialized literal of the given type and shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product();
        Literal::Array { ty, dims: dims.to_vec(), data: vec![0u8; n * ty.byte_size()] }
    }

    /// Rank-0 literal holding one value.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        let mut data = Vec::with_capacity(T::PRIMITIVE_TYPE.byte_size());
        x.write_le(&mut data);
        Literal::Array { ty: T::PRIMITIVE_TYPE, dims: Vec::new(), data }
    }

    /// Number of elements (1 for scalars; sum over components for tuples).
    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { ty, data, .. } => data.len() / ty.byte_size(),
            Literal::Tuple(xs) => xs.iter().map(Literal::element_count).sum(),
        }
    }

    /// The dimensions of an array literal.
    pub fn dims(&self) -> Result<&[usize]> {
        match self {
            Literal::Array { dims, .. } => Ok(dims),
            Literal::Tuple(_) => Err(Error::new("dims() called on a tuple literal")),
        }
    }

    /// Fill the buffer from a host slice; the element type and count must
    /// match the literal's shape.
    pub fn copy_raw_from<T: NativeType>(&mut self, src: &[T]) -> Result<()> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::PRIMITIVE_TYPE {
                    return Err(Error::new(format!(
                        "copy_raw_from: element type mismatch ({:?} literal, {:?} source)",
                        ty,
                        T::PRIMITIVE_TYPE
                    )));
                }
                if src.len() * ty.byte_size() != data.len() {
                    return Err(Error::new(format!(
                        "copy_raw_from: {} elements into a literal of {}",
                        src.len(),
                        data.len() / ty.byte_size()
                    )));
                }
                data.clear();
                for &x in src {
                    x.write_le(data);
                }
                Ok(())
            }
            Literal::Tuple(_) => Err(Error::new("copy_raw_from on a tuple literal")),
        }
    }

    /// Read the buffer back as a flat host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::PRIMITIVE_TYPE {
                    return Err(Error::new(format!(
                        "to_vec: element type mismatch ({:?} literal, {:?} requested)",
                        ty,
                        T::PRIMITIVE_TYPE
                    )));
                }
                Ok(data.chunks_exact(ty.byte_size()).map(T::read_le).collect())
            }
            Literal::Tuple(_) => Err(Error::new("to_vec on a tuple literal")),
        }
    }

    /// Decompose a tuple literal into its components.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(xs) => Ok(xs),
            lit @ Literal::Array { .. } => Ok(vec![lit]),
        }
    }

    /// Decompose a 1-tuple (or pass an array literal through).
    pub fn to_tuple1(self) -> Result<Literal> {
        let mut xs = self.to_tuple()?;
        if xs.len() != 1 {
            return Err(Error::new(format!("to_tuple1 on a {}-tuple", xs.len())));
        }
        Ok(xs.pop().expect("len checked"))
    }
}

/// A parsed HLO module (here: the raw text, kept for diagnostics).
pub struct HloModuleProto {
    text: String,
    path: String,
}

impl HloModuleProto {
    /// Read an HLO **text** artifact from disk. Parsing succeeds whenever
    /// the file is readable and non-empty; semantic validation happens in
    /// the real bindings only.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {}: {e}", path.display())))?;
        if text.trim().is_empty() {
            return Err(Error::new(format!("HLO text {} is empty", path.display())));
        }
        Ok(Self { text, path: path.display().to_string() })
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    source_path: String,
    source_len: usize,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { source_path: proto.path.clone(), source_len: proto.text.len() }
    }
}

/// A PJRT client. The stand-in reports a distinctive platform name so logs
/// cannot be mistaken for real PJRT output.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    /// Create the CPU client (always succeeds in the stand-in).
    pub fn cpu() -> Result<Self> {
        Ok(Self { platform: "cpu-stub (vendored xla stand-in; no HLO execution)" })
    }

    /// Platform name of this client.
    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    /// "Compile" a computation. The stand-in accepts any computation
    /// structurally; actual codegen is deferred to [`PjRtLoadedExecutable::execute`],
    /// which reports that execution needs the real bindings.
    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            source_path: computation.source_path.clone(),
            source_len: computation.source_len,
        })
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable {
    source_path: String,
    #[allow(dead_code)]
    source_len: usize,
}

impl PjRtLoadedExecutable {
    /// Execute the program. The stand-in cannot run HLO; it returns a
    /// descriptive error so callers fail loudly instead of silently
    /// producing wrong numbers.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(format!(
            "cannot execute {}: this build uses the vendored xla stand-in; \
             install the real xla_extension bindings to run compiled artifacts \
             (see README.md §Runtime)",
            self.source_path
        )))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let mut lit = Literal::create_from_shape(ElementType::F32.primitive_type(), &[2, 3]);
        assert_eq!(lit.element_count(), 6);
        lit.copy_raw_from(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.dims().unwrap(), &[2, 3]);
    }

    #[test]
    fn literal_roundtrip_i32_and_type_checks() {
        let mut lit = Literal::create_from_shape(ElementType::S32.primitive_type(), &[3]);
        lit.copy_raw_from(&[7i32, -1, 0]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, -1, 0]);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.copy_raw_from(&[1.0f32, 2.0, 3.0]).is_err());
        assert!(lit.copy_raw_from(&[1i32]).is_err());
    }

    #[test]
    fn scalar_and_tuples() {
        let s = Literal::scalar(0.25f32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![0.25]);

        let t = Literal::Tuple(vec![Literal::scalar(1i32), Literal::scalar(2i32)]);
        assert_eq!(t.element_count(), 2);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);

        let one = Literal::Tuple(vec![Literal::scalar(5.0f32)]);
        assert_eq!(one.to_tuple1().unwrap().to_vec::<f32>().unwrap(), vec![5.0]);
        let two = Literal::Tuple(vec![Literal::scalar(1i32), Literal::scalar(2i32)]);
        assert!(two.to_tuple1().is_err());
    }

    #[test]
    fn execution_is_a_loud_error() {
        let dir = std::env::temp_dir().join(format!("xla_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.hlo.txt");
        std::fs::write(&path, "HloModule m\n").unwrap();
        let proto = HloModuleProto::from_text_file(&path).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let exe = client.compile(&comp).unwrap();
        let args: Vec<&Literal> = Vec::new();
        let err = exe.execute::<&Literal>(&args).unwrap_err();
        assert!(err.to_string().contains("stand-in"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_or_empty_hlo_rejected() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
        let dir = std::env::temp_dir().join(format!("xla_stub_e_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.hlo.txt");
        std::fs::write(&path, "  \n").unwrap();
        assert!(HloModuleProto::from_text_file(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
