//! The §4.2 scenario as an application: you have a vertex sampling budget
//! (feature-fetch bandwidth, GPU memory, ...). How large a batch can each
//! sampler afford, and what does that do to convergence?
//!
//! ```bash
//! cargo run --release --example budget_batchsize -- [dataset] [budget]
//! ```

use labor_gnn::data::Dataset;
use labor_gnn::sampler::{IterSpec, SamplerKind};
use labor_gnn::tune::{mean_deepest_vertices, solve_batch_size};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(|s| s.as_str()).unwrap_or("flickr-sim");
    let ds = Dataset::load_or_generate(dataset, 0.1)?;
    let budget: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or_else(|| ds.budget_v3());
    let fanouts = [10usize, 10, 10];

    println!("dataset {dataset}: |V^3| sampling budget = {budget}");
    println!("{:<10} {:>12} {:>14}", "method", "batch size", "E[|V^3|] at bs");
    let methods = [
        ("LABOR-*", SamplerKind::Labor { iterations: IterSpec::Converge, layer_dependent: false }),
        ("LABOR-1", SamplerKind::Labor { iterations: IterSpec::Fixed(1), layer_dependent: false }),
        ("LABOR-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("NS", SamplerKind::Neighbor),
    ];
    let mut first = None;
    let mut last = 0usize;
    for (label, kind) in methods {
        let bs = solve_batch_size(&ds, &kind, &fanouts, budget, 5);
        let v3 = mean_deepest_vertices(&ds, &kind, &fanouts, bs, 5);
        println!("{label:<10} {bs:>12} {v3:>14.0}");
        if first.is_none() {
            first = Some(bs);
        }
        last = bs;
    }
    if let Some(f) = first {
        println!(
            "\nLABOR-* affords a {:.1}x larger batch than NS under the same budget.",
            f as f64 / last.max(1) as f64
        );
    }
    Ok(())
}
