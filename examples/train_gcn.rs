//! End-to-end driver: train the AOT-compiled 3-layer GCN on a synthetic
//! dataset with LABOR sampling, streaming batches through the parallel
//! sampling pipeline — features and labels gathered in-pipeline by the
//! data plane — and log the loss curve + validation F1.
//!
//! This is the whole stack in one binary: L3 Rust pipeline + samplers +
//! feature data plane → pre-gathered packed batches → L2/L1 compiled
//! JAX+Pallas train_step via PJRT.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_gcn -- [dataset] [steps] [method]
//! # e.g. cargo run --release --example train_gcn -- flickr-sim 200 labor-1
//! ```

use labor_gnn::coordinator::cache::NullCache;
use labor_gnn::coordinator::feature_store::TierModel;
use labor_gnn::coordinator::pipeline::{DataPlaneConfig, PipelineConfig, SamplingPipeline};
use labor_gnn::data::Dataset;
use labor_gnn::runtime::{Engine, Manifest};
use labor_gnn::sampler::{MultiLayerSampler, SamplerKind};
use labor_gnn::train::Trainer;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(|s| s.as_str()).unwrap_or("flickr-sim").to_string();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let method = args.get(2).map(|s| s.as_str()).unwrap_or("labor-1").to_string();

    let ds = Arc::new(Dataset::load_or_generate(&dataset, 0.1)?);
    let engine = Engine::cpu()?;
    let man = Manifest::load("artifacts")?;
    let model = engine.load_model(&man, &format!("gcn_{dataset}"))?;
    let batch_size = model.cfg.batch_size;
    let kind =
        SamplerKind::parse(&method).expect("method: ns|labor-0|labor-1|labor-*|ladies-512,256");
    let sampler = Arc::new(MultiLayerSampler::new(kind, &[10, 10, 10]));
    anyhow::ensure!(
        sampler.num_layers() == model.cfg.num_layers(),
        "method '{method}' samples {} layers but artifact gcn_{dataset} is {}-layer — \
         pass one budget per layer (e.g. ladies-2000,1000,500)",
        sampler.num_layers(),
        model.cfg.num_layers()
    );
    let eval_sampler = MultiLayerSampler::new(sampler.kind.clone(), &[10, 10, 10]);
    let mut trainer = Trainer::new(model, 42)?;

    println!(
        "training gcn_{dataset} with {} for {steps} steps (batch {batch_size})",
        sampler.name()
    );

    // streaming pipeline: 4 sampler workers, depth-4 backpressure queue,
    // and the data plane — workers gather features + labels while the
    // consumer runs the previous train_step
    let plane = DataPlaneConfig::for_dataset(&ds, TierModel::local(), Arc::new(NullCache));
    let mut pipeline = SamplingPipeline::spawn(
        Arc::new(ds.graph.clone()),
        sampler,
        Arc::new(ds.splits.train.clone()),
        PipelineConfig {
            num_workers: 4,
            queue_depth: 4,
            batch_size,
            num_batches: steps,
            seed: 42,
            intra_batch_threads: 1,
            data_plane: Some(plane),
            output_perm: None,
        },
    );

    let t0 = std::time::Instant::now();
    for batch in &mut pipeline {
        // the batch carries pre-gathered features/labels — the trainer
        // never touches the dataset on this path
        let rec = trainer.step_batch(&batch)?;
        if rec.step % 20 == 0 || rec.step == 1 || rec.step == steps {
            let val = &ds.splits.val[..2048.min(ds.splits.val.len())];
            let f1 = trainer.evaluate(&ds, &eval_sampler, val, 0xE7A1)?;
            println!(
                "step {:>5}  loss {:>8.4}  val F1 {:>7.4}  cum|V| {:>10}  {:>6.2} it/s",
                rec.step,
                rec.loss,
                f1,
                rec.cum_vertices,
                rec.step as f64 / t0.elapsed().as_secs_f64()
            );
        }
    }
    let stages = pipeline.stage_metrics();
    pipeline.join();

    let test = &ds.splits.test[..4096.min(ds.splits.test.len())];
    let f1 = trainer.evaluate(&ds, &eval_sampler, test, 0x7E57)?;
    println!(
        "done in {:.1}s — test F1 {:.4} (overflow edges dropped: {})",
        t0.elapsed().as_secs_f64(),
        f1,
        trainer.overflow_edges
    );
    println!(
        "pipeline stages per batch: sample {:.2} ms, gather {:.2} ms, queue-wait {:.2} ms",
        stages.mean_sample_ms(),
        stages.mean_gather_ms(),
        stages.mean_queue_wait_ms()
    );
    Ok(())
}
