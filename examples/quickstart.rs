//! Quickstart: sample a 3-layer message-flow graph with LABOR and compare
//! its size against Neighbor Sampling — the paper's headline effect in
//! twenty lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use labor_gnn::data::Dataset;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind, SamplerScratch};

fn main() -> anyhow::Result<()> {
    // Table-1-calibrated synthetic stand-in for flickr (|V|≈8.9k, deg≈10)
    let ds = Dataset::load_or_generate("flickr-sim", 0.1)?;
    println!(
        "dataset {}: |V|={} |E|={} avg deg {:.1}",
        ds.spec.name,
        ds.graph.num_vertices(),
        ds.graph.num_edges(),
        ds.graph.avg_degree()
    );

    let seeds: Vec<u32> = ds.splits.train[..1000.min(ds.splits.train.len())].to_vec();
    let fanouts = [10, 10, 10];

    // one reusable scratch arena: repeated sampling performs no per-batch
    // O(|V|) allocation (one-off callers can use `sample_fresh` instead)
    let mut scratch = SamplerScratch::new();
    for (label, kind) in [
        ("NS      ", SamplerKind::Neighbor),
        ("LABOR-0 ", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("LABOR-* ", SamplerKind::Labor { iterations: IterSpec::Converge, layer_dependent: false }),
    ] {
        let sampler = MultiLayerSampler::new(kind, &fanouts);
        let mfg = sampler.sample(&ds.graph, &seeds, 0, &mut scratch);
        println!(
            "{label} |V^1..3| = {:?}  |E^0..2| = {:?}",
            mfg.vertex_counts(),
            mfg.edge_counts()
        );
    }
    println!("\nSame fanout, same estimator-variance target — fewer vertices. That's LABOR.");
    Ok(())
}
