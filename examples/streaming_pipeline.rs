//! Data-plane scenario: stream sampled batches through the bounded
//! coordinator queue with the feature gather running *inside* the
//! pipeline workers against a shared store with a simulated slow tier,
//! optionally fronted by a degree-ordered cache — and measure how each
//! sampler's *vertex* efficiency turns into end-to-end throughput when
//! features live behind PCI-e / NVMe (paper §4.1, "feature access speed"
//! discussion).
//!
//! ```bash
//! cargo run --release --example streaming_pipeline -- [dataset] [tier] [cache_rows]
//! # tier: local | pcie | nvme;  cache_rows: 0 = no cache (default),
//! # otherwise the top-k in-degree rows are pinned in the fast tier
//! ```

use labor_gnn::coordinator::cache::{DegreeOrderedCache, FeatureCache, NullCache};
use labor_gnn::coordinator::feature_store::{FeatureStore, TierModel};
use labor_gnn::coordinator::pipeline::{DataPlaneConfig, PipelineConfig, SamplingPipeline};
use labor_gnn::data::Dataset;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(|s| s.as_str()).unwrap_or("flickr-sim");
    let tier = args
        .get(1)
        .and_then(|s| TierModel::parse(s))
        .unwrap_or_else(TierModel::pcie);
    let cache_rows: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0);
    let ds = Arc::new(Dataset::load_or_generate(dataset, 0.1)?);
    // Arc-shared with the dataset: the store references the rows in place
    let feats: Arc<Vec<f32>> = ds.features.clone();
    let batches = 50u64;

    println!(
        "{:<10} {:>10} {:>10} {:>9} {:>7} {:>12} {:>10}",
        "method", "batches/s", "MB moved", "MB saved", "hit%", "mean |V^3|", "gather ms"
    );
    // one policy instance shared by all three runs (it is immutable)
    let cache: Arc<dyn FeatureCache> = if cache_rows == 0 {
        Arc::new(NullCache)
    } else {
        Arc::new(DegreeOrderedCache::new(&ds.graph, cache_rows))
    };
    for (label, kind) in [
        ("NS", SamplerKind::Neighbor),
        ("LABOR-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("LABOR-*", SamplerKind::Labor { iterations: IterSpec::Converge, layer_dependent: false }),
    ] {
        let store = Arc::new(
            FeatureStore::new(feats.clone(), ds.spec.num_features, tier)
                .with_cache(cache.clone()),
        );
        let sampler = Arc::new(MultiLayerSampler::new(kind, &[10, 10, 10]));
        let mut pipeline = SamplingPipeline::spawn(
            Arc::new(ds.graph.clone()),
            sampler,
            Arc::new(ds.splits.train.clone()),
            PipelineConfig {
                num_workers: 4,
                queue_depth: 4,
                batch_size: 1024,
                num_batches: batches,
                seed: 9,
                intra_batch_threads: 1,
                data_plane: Some(DataPlaneConfig { store: store.clone(), labels: None }),
                output_perm: None,
            },
        );
        let mut v3 = 0usize;
        let t0 = std::time::Instant::now();
        for b in &mut pipeline {
            // features arrive pre-gathered — the consumer only consumes;
            // this is the traffic LABOR minimizes
            v3 += b.mfg.feature_vertices().len();
            std::hint::black_box(&b.feats);
        }
        let stages = pipeline.stage_metrics();
        pipeline.join();
        // serialize the simulated fetch on top of the wall clock — the
        // pessimistic single-DMA-engine reading of the tier model
        let wall = t0.elapsed().as_secs_f64() + store.simulated_time().as_secs_f64();
        println!(
            "{:<10} {:>10.2} {:>10.1} {:>9.1} {:>7.1} {:>12.0} {:>10.3}",
            label,
            batches as f64 / wall,
            store.bytes_fetched() as f64 / 1e6,
            store.bytes_saved() as f64 / 1e6,
            store.hit_rate() * 100.0,
            v3 as f64 / batches as f64,
            stages.mean_gather_ms()
        );
    }
    println!(
        "\nFewer sampled vertices => less feature traffic => higher pipeline throughput \
         on slow tiers; a degree-ordered cache compounds the saving."
    );
    Ok(())
}
