//! Data-pipeline scenario: stream sampled batches through the bounded
//! coordinator queue with a simulated slow feature tier, and measure how
//! each sampler's *vertex* efficiency turns into end-to-end throughput
//! when features live behind PCI-e / NVMe (paper §4.1, "feature access
//! speed" discussion).
//!
//! ```bash
//! cargo run --release --example streaming_pipeline -- [dataset] [tier]
//! # tier: local | pcie | nvme
//! ```

use labor_gnn::coordinator::feature_store::{FeatureStore, TierModel};
use labor_gnn::coordinator::pipeline::{PipelineConfig, SamplingPipeline};
use labor_gnn::data::Dataset;
use labor_gnn::sampler::{IterSpec, MultiLayerSampler, SamplerKind};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(|s| s.as_str()).unwrap_or("flickr-sim");
    let tier = match args.get(1).map(|s| s.as_str()).unwrap_or("pcie") {
        "local" => TierModel::local(),
        "nvme" => TierModel::nvme(),
        _ => TierModel::pcie(),
    };
    let ds = Arc::new(Dataset::load_or_generate(dataset, 0.1)?);
    let batches = 50u64;

    println!(
        "{:<10} {:>10} {:>12} {:>14} {:>12}",
        "method", "batches/s", "MB fetched", "sim fetch (ms)", "mean |V^3|"
    );
    for (label, kind) in [
        ("NS", SamplerKind::Neighbor),
        ("LABOR-0", SamplerKind::Labor { iterations: IterSpec::Fixed(0), layer_dependent: false }),
        ("LABOR-*", SamplerKind::Labor { iterations: IterSpec::Converge, layer_dependent: false }),
    ] {
        let sampler = Arc::new(MultiLayerSampler::new(kind, &[10, 10, 10]));
        let mut pipeline = SamplingPipeline::spawn(
            Arc::new(ds.graph.clone()),
            sampler,
            Arc::new(ds.splits.train.clone()),
            PipelineConfig {
                num_workers: 4,
                queue_depth: 4,
                batch_size: 1024,
                num_batches: batches,
                seed: 9,
                intra_batch_threads: 1,
            },
        );
        let mut store = FeatureStore::new(&ds.features, ds.spec.num_features, tier);
        let mut rows = Vec::new();
        let mut v3 = 0usize;
        let t0 = std::time::Instant::now();
        for b in &mut pipeline {
            // the consumer fetches features for the deepest layer inputs —
            // this is the traffic LABOR minimizes
            store.gather(b.mfg.feature_vertices(), &mut rows);
            v3 += b.mfg.feature_vertices().len();
        }
        pipeline.join();
        let wall = t0.elapsed().as_secs_f64() + store.simulated_time.as_secs_f64();
        println!(
            "{:<10} {:>10.2} {:>12.1} {:>14.1} {:>12.0}",
            label,
            batches as f64 / wall,
            store.bytes_fetched as f64 / 1e6,
            store.simulated_time.as_secs_f64() * 1e3,
            v3 as f64 / batches as f64
        );
    }
    println!(
        "\nFewer sampled vertices => less feature traffic => higher pipeline throughput on slow tiers."
    );
    Ok(())
}
