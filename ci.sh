#!/usr/bin/env bash
# CI gate for the labor-gnn workspace. Run from the repository root.
#
#   ./ci.sh          # full gate: format, lints, build, tests, docs
#   ./ci.sh fast     # same gate minus the release build
set -euo pipefail
cd "$(dirname "$0")"

MODE="${1:-full}"

echo "== cargo fmt --check"
cargo fmt --all --check

echo "== cargo clippy (workspace, all targets, -D warnings)"
cargo clippy --workspace --all-targets -- -D warnings

if [ "$MODE" != "fast" ]; then
  echo "== cargo build --release (tier-1, step 1/2)"
  cargo build --release
fi

echo "== cargo test -q (tier-1, step 2/2)"
cargo test -q

echo "== scalar-fallback pass: full test suite with SIMD/prefetch forced off"
# LABOR_NO_SIMD=1 routes FeatureStore::gather, the serving demux, and the
# sampler frontier walks through their scalar/unhinted paths; the suite —
# including the bit-identity tests — must stay green on both paths
LABOR_NO_SIMD=1 cargo test -q

echo "== hardened-reader + identity tests, explicitly"
# corrupt/forged-length files must fail with named errors (never a panic
# or an OOM-sized allocation), mmap and buffered .lgx loads must be
# bit-identical, and SIMD must match scalar to the bit for every sampler
cargo test -q --test io_hardening --test simd_identity --test lgx_format

echo "== chaos suite: fault injection, supervised recovery, degradation"
# deterministic failpoint schedules against the serving front end and the
# sampling pipeline: a 1k-request chaos stream completes with zero silent
# drops, the same schedule replays bit-identically, overload sheds with
# named errors, and the fanout-degradation ladder steps down and recovers
cargo test -q --test chaos

echo "== execution-engine identity suite: pool, plan, memo"
# the hot-path machinery is acceleration only: pooled shards ≡ scoped
# spawns ≡ sequential, plan-enabled ≡ plan-less, memoized ≡ fresh — all
# to the bit — and supervised respawn chaos must not leak pool threads
cargo test -q --test hotpath_identity --test parallel_identity

echo "== partition identity suite: partition-aware sampling + split-store gathers"
# a partition-major relabel plus an attached partition map may only move
# accounting: sharded sampling and split-store gathers must stay
# bit-identical to the unpartitioned path for every kind × shard count ×
# K — on the pooled engine AND the spawn-per-call fallback
cargo test -q --test partition_identity
LABOR_NO_POOL=1 cargo test -q --test partition_identity

echo "== spawn-fallback pass: full test suite with the shard pool forced off"
# LABOR_NO_POOL=1 routes every sharded sample through freshly scoped
# spawn-per-call threads (the pre-pool engine); the suite — including the
# bit-identity tests — must stay green on both execution modes
LABOR_NO_POOL=1 cargo test -q

if [ "$MODE" != "fast" ]; then
  echo "== graph-pack smoke: .lgx pack + verified reload via the repro CLI"
  # packs the tiny dataset into the zero-copy format (degree-ordered
  # layout + perm section), reloads it, and checks graph/perm equality —
  # the command exits nonzero on any mismatch or checksum failure
  ./target/release/repro graph pack --dataset tiny --scale 0.2 \
    --out "${TMPDIR:-/tmp}/labor_ci_tiny.lgx"
  rm -f "${TMPDIR:-/tmp}/labor_ci_tiny.lgx"

  echo "== partition-pack smoke: LDG layout + parts section via the repro CLI"
  # partition-major relabel (LDG, K=4) with the PartitionMap stored in the
  # .lgx parts section; the command reloads through both loaders and exits
  # nonzero on any graph/perm/parts mismatch
  ./target/release/repro graph pack --dataset tiny --scale 0.2 \
    --layout partition:4 --out "${TMPDIR:-/tmp}/labor_ci_parts.lgx"
  rm -f "${TMPDIR:-/tmp}/labor_ci_parts.lgx"

  echo "== bench-smoke: build all bench targets, run pipeline + samplers tiny"
  cargo build --release --benches
  # --smoke: tiny iteration counts; proves the throughput sections, the
  # data-plane gather sweep, the graph-engine locality sweep, and the
  # allocation probe run end-to-end (see docs/BENCHMARKS.md); remove any
  # stale perf records first so the existence checks below can't pass on
  # them
  rm -f BENCH_pipeline.json BENCH_datapipe.json BENCH_graph.json BENCH_serving.json \
    BENCH_chaos.json BENCH_hotpath.json BENCH_partition.json
  cargo bench --bench pipeline -- --smoke
  cargo bench --bench samplers -- --smoke
  # partition engine: LDG vs random vs contiguous edge-cut quality, the
  # local-hit fraction of split-store gathers (the bench asserts LDG beats
  # random), remote-tier priced gathers, and the NS-over-LABOR-0
  # remote-byte amplification — identity-checked before timing
  cargo bench --bench partition -- --smoke
  # execution-engine micro-bench: persistent-pool vs spawn-per-call shard
  # latency, static-π plan vs live weighted solver, and the hot-vertex
  # memo hit rate under a Zipf stream — each identity-checked before it
  # is timed
  cargo bench --bench hotpath -- --smoke
  # serving QoS sweep: coalesced-LABOR vs one-at-a-time NS across arrival
  # rates × window sizes; the bench asserts the headline (coalesced
  # LABOR-0 gathers fewer feature bytes per request than solo NS under
  # load) and records p50/p99 latency + bytes/request per series
  cargo bench --bench serving -- --smoke
  # the smoke runs must leave all machine-readable perf records behind:
  # batches/s per thread count, feature bytes moved per sampler × tier ×
  # cache (the bench itself asserts LABOR-0 < NS bytes), and the
  # original-vs-relabeled sampling/gather sweep + .lgx load-vs-text-parse
  # comparison (the samplers bench asserts hit-accounting equivalence and
  # three-way load agreement)
  test -f BENCH_pipeline.json || { echo "BENCH_pipeline.json missing"; exit 1; }
  test -f BENCH_datapipe.json || { echo "BENCH_datapipe.json missing"; exit 1; }
  test -f BENCH_graph.json || { echo "BENCH_graph.json missing"; exit 1; }
  test -f BENCH_serving.json || { echo "BENCH_serving.json missing"; exit 1; }
  test -f BENCH_chaos.json || { echo "BENCH_chaos.json missing"; exit 1; }
  test -f BENCH_hotpath.json || { echo "BENCH_hotpath.json missing"; exit 1; }
  test -f BENCH_partition.json || { echo "BENCH_partition.json missing"; exit 1; }
  # this PR's partition-engine records: cut quality, gather locality, and
  # the frontier-as-traffic amplification headline
  grep -q '"cut_fraction_ldg"' BENCH_partition.json \
    || { echo "BENCH_partition.json is missing the cut-quality record"; exit 1; }
  grep -q '"local_hit_ldg"' BENCH_partition.json \
    || { echo "BENCH_partition.json is missing the local-hit record"; exit 1; }
  grep -q '"priced_gather_us_unpartitioned"' BENCH_partition.json \
    || { echo "BENCH_partition.json is missing the priced-gather record"; exit 1; }
  grep -q '"remote_amplification_ns_over_labor0"' BENCH_partition.json \
    || { echo "BENCH_partition.json is missing the amplification record"; exit 1; }
  # this PR's execution-engine records: pool and plan speedups plus the
  # memoized-serving hit rates (micro-bench and serving-level)
  grep -q '"pool_speedup"' BENCH_hotpath.json \
    || { echo "BENCH_hotpath.json is missing the pool-speedup record"; exit 1; }
  grep -q '"plan_speedup"' BENCH_hotpath.json \
    || { echo "BENCH_hotpath.json is missing the plan-speedup record"; exit 1; }
  grep -q '"memo_hit_rate"' BENCH_hotpath.json \
    || { echo "BENCH_hotpath.json is missing the memo-hit-rate record"; exit 1; }
  grep -q '"serving_memo_hit_rate"' BENCH_serving.json \
    || { echo "BENCH_serving.json is missing the memoized-serving record"; exit 1; }
  # this PR's memory-system records must be present: the mmap-vs-buffered
  # .lgx load series and the SIMD-vs-scalar gather micro-bench
  grep -q '"lgx_mmap_load_s"' BENCH_graph.json \
    || { echo "BENCH_graph.json is missing the mmap-load record"; exit 1; }
  grep -q '"simd_gather"' BENCH_datapipe.json \
    || { echo "BENCH_datapipe.json is missing the simd-gather record"; exit 1; }
  # this PR's robustness records: tail latency under the degradation
  # ladder and the admission shed rate of the overload series
  grep -q '"degraded_p99_ms"' BENCH_chaos.json \
    || { echo "BENCH_chaos.json is missing the degraded-p99 record"; exit 1; }
  grep -q '"shed_rate"' BENCH_chaos.json \
    || { echo "BENCH_chaos.json is missing the shed-rate record"; exit 1; }
  echo "== BENCH_pipeline.json:"
  cat BENCH_pipeline.json
  echo "== BENCH_datapipe.json:"
  cat BENCH_datapipe.json
  echo "== BENCH_graph.json:"
  cat BENCH_graph.json
  echo "== BENCH_serving.json:"
  cat BENCH_serving.json
  echo "== BENCH_chaos.json:"
  cat BENCH_chaos.json
  echo "== BENCH_hotpath.json:"
  cat BENCH_hotpath.json
  echo "== BENCH_partition.json:"
  cat BENCH_partition.json

  echo "== serve smoke: online coalescing front end via the repro CLI"
  # a short Zipf request stream through `repro serve` (deadline-window
  # coalescing + demux) with the execution engine fully on: a 2-thread
  # shard pool, the static-π plan cache (default), and full-graph sample
  # memoization; the command asserts its own bookkeeping (served +
  # missed == requests, per-response accounting, plan enabled, memo
  # counters moved, pool threads live) and prints the QoS summary
  ./target/release/repro serve --dataset flickr-sim --scale 0.1 \
    --method labor-0 --rate 4000 --window-us 1000 \
    --pool-threads 2 --sample-memo-rows 1000000 --smoke

  echo "== chaos serve smoke: supervised recovery + degradation via the CLI"
  # same front end under an armed failpoint schedule: flush panics every
  # 40th hit and transient gather errors every 25th, a supervised worker,
  # bounded admission, the 10,7,4 degradation ladder, and the plan cache
  # disabled (the --no-plan-cache escape hatch must keep working); the
  # command asserts outcome conservation (served + missed + invalid +
  # failed + died + shed == requests) and that chaos stayed armed end to
  # end
  ./target/release/repro serve --dataset flickr-sim --scale 0.1 \
    --method labor-0 --rate 4000 --window-us 1000 \
    --policy supervise --max-restarts 50 --max-queue 256 \
    --degrade-ladder 10,7,4 --no-plan-cache \
    --chaos 'sample_flush=panic@every40;gather=error@every25' --smoke

  echo "== partitioned serve smoke: split-store gathers behind the front end"
  # the same front end serving from a partition-major relabeled graph
  # whose features are split across 4 per-partition stores: the command
  # asserts the partitioned store saw every gather and prints the
  # local-hit fraction and remote-hop pricing
  ./target/release/repro serve --dataset flickr-sim --scale 0.1 \
    --method labor-0 --rate 4000 --window-us 1000 \
    --partitions 4 --smoke
fi

echo "== cargo doc --no-deps (rustdoc must be warning-free)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace --quiet

echo "CI gate passed."
