#!/usr/bin/env bash
# CI gate for the labor-gnn workspace. Run from the repository root.
#
#   ./ci.sh          # full gate: format, lints, build, tests, docs
#   ./ci.sh fast     # same gate minus the release build
set -euo pipefail
cd "$(dirname "$0")"

MODE="${1:-full}"

echo "== cargo fmt --check"
cargo fmt --all --check

echo "== cargo clippy (workspace, all targets, -D warnings)"
cargo clippy --workspace --all-targets -- -D warnings

if [ "$MODE" != "fast" ]; then
  echo "== cargo build --release (tier-1, step 1/2)"
  cargo build --release
fi

echo "== cargo test -q (tier-1, step 2/2)"
cargo test -q

if [ "$MODE" != "fast" ]; then
  echo "== bench-smoke: build all bench targets, run the pipeline bench tiny"
  cargo build --release --benches
  # --smoke: tiny iteration counts; proves the throughput sections and the
  # allocation probe run end-to-end (see docs/BENCHMARKS.md); remove any
  # stale perf record first so the existence check below can't pass on it
  rm -f BENCH_pipeline.json
  cargo bench --bench pipeline -- --smoke
  # the smoke run must leave the machine-readable perf trajectory behind
  # (sequential vs sharded batches/s per thread count)
  test -f BENCH_pipeline.json || { echo "BENCH_pipeline.json missing"; exit 1; }
  echo "== BENCH_pipeline.json:"
  cat BENCH_pipeline.json
fi

echo "== cargo doc --no-deps (rustdoc must be warning-free)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace --quiet

echo "CI gate passed."
