#!/usr/bin/env bash
# CI gate for the labor-gnn workspace. Run from the repository root.
#
#   ./ci.sh          # full gate: format, lints, build, tests, docs
#   ./ci.sh fast     # same gate minus the release build
set -euo pipefail
cd "$(dirname "$0")"

MODE="${1:-full}"

echo "== cargo fmt --check"
cargo fmt --all --check

echo "== cargo clippy (workspace, all targets, -D warnings)"
cargo clippy --workspace --all-targets -- -D warnings

if [ "$MODE" != "fast" ]; then
  echo "== cargo build --release (tier-1, step 1/2)"
  cargo build --release
fi

echo "== cargo test -q (tier-1, step 2/2)"
cargo test -q

if [ "$MODE" != "fast" ]; then
  echo "== bench-smoke: build all bench targets, run the pipeline bench tiny"
  cargo build --release --benches
  # --smoke: tiny iteration counts; proves the throughput sections, the
  # data-plane gather sweep, and the allocation probe run end-to-end (see
  # docs/BENCHMARKS.md); remove any stale perf records first so the
  # existence checks below can't pass on them
  rm -f BENCH_pipeline.json BENCH_datapipe.json
  cargo bench --bench pipeline -- --smoke
  # the smoke run must leave both machine-readable perf records behind:
  # batches/s per thread count, and feature bytes moved per sampler ×
  # tier × cache (the bench itself asserts LABOR-0 < NS bytes)
  test -f BENCH_pipeline.json || { echo "BENCH_pipeline.json missing"; exit 1; }
  test -f BENCH_datapipe.json || { echo "BENCH_datapipe.json missing"; exit 1; }
  echo "== BENCH_pipeline.json:"
  cat BENCH_pipeline.json
  echo "== BENCH_datapipe.json:"
  cat BENCH_datapipe.json
fi

echo "== cargo doc --no-deps (rustdoc must be warning-free)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace --quiet

echo "CI gate passed."
